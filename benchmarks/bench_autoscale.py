"""Autoscaling benchmark: bursty demand vs a peak-sized static fleet.

One request trace is replayed as a WALL-CLOCK arrival schedule — a square
wave (bursts of Poisson arrivals separated by quiet gaps) — against two
fleets serving the same FleetDispatcher pool machinery:

* ``static`` — n_peak pilots provisioned up front and held for the whole
  run: the fleet a peak-sizing capacity plan pays for;
* ``autoscaled`` — the demand-driven control loop (``core/autoscaler.py``)
  starts small, grows from queue pressure (prefetching the image so new
  pilots bind warm), and drains idle pilots in the gaps.

Acceptance gates (the run RAISES on violation):

* zero lost or duplicated requests — 100% completion and every token
  stream BITWISE equal to a single-engine baseline (greedy decode over
  identical weights is deterministic, so requeue/drain churn must not
  change a single token);
* the autoscaled fleet consumes <= 60% of the static fleet's
  pilot-seconds (slice-holding wall time, the resource bill);
* autoscaled p99 pool-level TTFT <= 3x the static fleet's (elasticity
  must not wreck the tail);
* zero scale-flapping: no consecutive opposite-direction decisions inside
  one cooldown window (``FleetAutoscaler.flaps()``).

``run_smoke`` is the CI lane: a single burst into a 1-pilot fleet must
ramp to the policy target within the cooldown budget, and after the trace
settles the loop must reclaim EVERY pilot (scale-to-zero on an empty
trace) — plus the completion/token gates above.
"""

from __future__ import annotations

import jax

from repro.configs.base import get_smoke_config
from repro.core.autoscaler import AutoscalePolicy
from repro.core.images import ExecutableRegistry
from repro.launch.serve import (make_bursty_schedule, make_trace,
                                serve_fleet_schedule)
from repro.models.api import build_model
from repro.serving.engine import ServeEngine

ARCH = "smollm-360m"
MAX_LEN = 64
SLOTS = 2
LEASE_TTL = 0.5


def _baseline_tokens(cfg, trace, slots: int) -> dict:
    """One pre-warmed engine, the whole trace at once — the bitwise token
    reference every fleet scenario must reproduce."""
    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN)
    eng.warm_admission()
    eng.warm_install()
    eng.run_trace([{**e, "at_step": 0} for e in trace])
    return {rid: list(r.tokens) for rid, r in eng.done.items()}


def _check_tokens(label: str, n_requests: int, out: dict, base: dict):
    if out["completed"] != n_requests or out["failed"]:
        raise RuntimeError(
            f"{label}: completed {out['completed']}/{n_requests} "
            f"(failed {out['failed']}) — scaling churn lost requests")
    for rid, toks in out["results"].items():
        if list(toks) != list(base[rid]):
            raise RuntimeError(
                f"{label}: rid {rid} token stream diverged from the "
                f"single-engine baseline (scaling churn corrupted a "
                f"request)")


def _check_no_flaps(out: dict):
    flaps = out["autoscale"]["flaps"]
    if flaps:
        raise RuntimeError(
            f"autoscaler flapped: {flaps} consecutive opposite-direction "
            f"decisions inside one cooldown window (gate: 0)")


def run(n_peak: int = 4, bursts: int = 3, burst_n: int = 16
        ) -> list[tuple[str, float, str]]:
    cfg = get_smoke_config(ARCH)
    n_requests = bursts * burst_n
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN, seed=0)
    base = _baseline_tokens(cfg, trace, n_peak * SLOTS)
    schedule = make_bursty_schedule(trace, bursts=bursts, burst_s=0.6,
                                    gap_s=6.0, seed=0)
    registry = ExecutableRegistry()      # shared: both fleets reuse compiles

    static = serve_fleet_schedule(
        ARCH, schedule, slots=SLOTS, max_len=MAX_LEN, n_pilots=n_peak,
        lease_ttl=LEASE_TTL, registry=registry)
    _check_tokens("static", n_requests, static, base)

    policy = AutoscalePolicy(
        min_pilots=1, max_pilots=n_peak, slots_per_pilot=SLOTS,
        interval=0.15, up_cooldown=0.4, down_cooldown=1.5,
        down_stable_ticks=4)
    auto = serve_fleet_schedule(
        ARCH, schedule, slots=SLOTS, max_len=MAX_LEN, policy=policy,
        initial_pilots=1, lease_ttl=LEASE_TTL, registry=registry,
        settle_to_zero=False)
    _check_tokens("autoscaled", n_requests, auto, base)
    _check_no_flaps(auto)

    ps_ratio = (auto["pilot_seconds"] / static["pilot_seconds"]
                if static["pilot_seconds"] else float("inf"))
    if ps_ratio > 0.6:
        raise RuntimeError(
            f"autoscaled fleet consumed {ps_ratio:.2f}x the static fleet's "
            f"pilot-seconds (gate: <= 0.6 — scaling saved too little)")
    ttft_ratio = (auto["ttft_p99_s"] / static["ttft_p99_s"]
                  if static["ttft_p99_s"] else float("inf"))
    if ttft_ratio > 3.0:
        raise RuntimeError(
            f"autoscaled p99 TTFT is {ttft_ratio:.2f}x the static fleet's "
            f"(gate: <= 3x — ramps landed on the latency path)")

    a = auto["autoscale"]
    detail = (f"{ARCH}, {bursts}x{burst_n} reqs burst/gap 0.6s/6s, peak "
              f"{n_peak} pilots x {SLOTS} slots")
    return [
        ("autoscale_completed", float(auto["completed"]),
         f"of {n_requests} (token streams bitwise == single-engine "
         f"baseline; raises otherwise)"),
        ("autoscale_pilot_seconds", auto["pilot_seconds"], detail),
        ("autoscale_static_pilot_seconds", static["pilot_seconds"],
         f"peak-sized static fleet, same schedule"),
        ("autoscale_pilot_seconds_ratio", ps_ratio,
         "autoscaled / static slice-holding cost (gate: <= 0.6)"),
        ("autoscale_ttft_p99_s", auto["ttft_p99_s"],
         "pool-level TTFT incl. ramp delay"),
        ("autoscale_static_ttft_p99_s", static["ttft_p99_s"],
         "peak-sized static fleet"),
        ("autoscale_ttft_p99_ratio", ttft_ratio,
         "autoscaled / static p99 TTFT (gate: <= 3)"),
        ("autoscale_scale_ups", float(a["scale_ups"]),
         f"{a['pilots_added']} pilots added across ramps"),
        ("autoscale_scale_downs", float(a["scale_downs"]),
         f"{a['pilots_drained']} pilots drained in the gaps"),
        ("autoscale_peak_pilots", float(a["peak_live"]),
         f"of {n_peak} allowed"),
        ("autoscale_flaps", float(a["flaps"]),
         "opposite-direction decisions inside one cooldown (gate: 0)"),
        ("autoscale_duplicates", float(auto["duplicates"]),
         "completions dropped by first-wins (drain churn never "
         "double-delivers)"),
        ("autoscale_replays", float(auto["replays"]),
         "re-dispatches: drained pilots' released requests"),
    ]


def run_smoke(n_requests: int = 16, n_peak: int = 3
              ) -> list[tuple[str, float, str]]:
    """CI smoke: one burst at t=0 into a 1-pilot fleet.  Gates: the ramp
    1->target completes within the cooldown budget, every request
    completes with bitwise-baseline tokens, no flapping, and after the
    trace drains the loop scales to ZERO (all pilots reclaimed, members
    and ClusterSim registries pruned)."""
    cfg = get_smoke_config(ARCH)
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN, seed=0)
    base = _baseline_tokens(cfg, trace, n_peak * SLOTS)
    registry = ExecutableRegistry()
    policy = AutoscalePolicy(
        min_pilots=0, max_pilots=n_peak, slots_per_pilot=SLOTS,
        interval=0.1, up_cooldown=0.3, down_cooldown=0.8,
        down_stable_ticks=3)
    schedule = [(0.0, e) for e in trace]      # the whole burst at once
    out = serve_fleet_schedule(
        ARCH, schedule, slots=SLOTS, max_len=MAX_LEN, policy=policy,
        initial_pilots=1, lease_ttl=LEASE_TTL, registry=registry,
        settle_to_zero=True)
    _check_tokens("autoscale_smoke", n_requests, out, base)
    _check_no_flaps(out)

    ups = [d for d in out["decisions"] if d["direction"] == "up"]
    if not ups:
        raise RuntimeError(
            "a burst into a 1-pilot fleet produced no scale-up decision")
    ramp_s = ups[-1]["t"] - out["t_start"]
    budget = len(ups) * policy.up_cooldown + 2.0
    if ramp_s > budget:
        raise RuntimeError(
            f"ramp to steady state took {ramp_s:.2f}s — outside the "
            f"cooldown budget ({len(ups)} up decisions x "
            f"{policy.up_cooldown}s + 2s slack = {budget:.2f}s)")
    if not out.get("scaled_to_zero"):
        raise RuntimeError(
            "scale-to-zero failed: pilots were not reclaimed after the "
            "trace drained")
    return [
        ("autoscale_smoke_completed", float(out["completed"]),
         f"of {n_requests}, tokens bitwise == single-engine baseline"),
        ("autoscale_smoke_ramp_s", ramp_s,
         f"burst -> last scale-up decision (budget {budget:.1f}s)"),
        ("autoscale_smoke_pilots_added", float(
            out["autoscale"]["pilots_added"]),
         f"1 -> up to {n_peak} pilots on queue pressure"),
        ("autoscale_smoke_scaled_to_zero", 1.0,
         f"all pilots reclaimed {out['scale_to_zero_s']:.2f}s after the "
         f"trace drained (raises otherwise)"),
        ("autoscale_smoke_flaps", float(out["autoscale"]["flaps"]),
         "gate: 0"),
    ]
