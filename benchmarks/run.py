"""Benchmark harness: one module per paper claim/figure.

  PYTHONPATH=src python -m benchmarks.run [--only bind,sched,...]

Prints ``name,value,detail`` CSV.  The dry-run roofline table (the TPU-
target performance report) is separate: ``python -m benchmarks.roofline``.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_autoscale, bench_bind, bench_chaos,
                        bench_disagg, bench_fleet_serve, bench_lifecycle,
                        bench_monitor, bench_scheduler, bench_serving,
                        bench_spec_decode, bench_tp_serve, bench_train,
                        roofline)

SUITES = {
    "bind": bench_bind.run,            # paper Fig. 4: late-binding cost
    "lifecycle": bench_lifecycle.run,  # paper Fig. 2: step costs a-h
    "sched": bench_scheduler.run,      # overlay scheduler throughput
    "monitor": bench_monitor.run,      # paper §3.4 monitor overhead
    "serving": bench_serving.run,      # payload-side serving numbers
    "serving_paged": bench_serving.run_smoke,  # paged-vs-dense CI smoke
    "fleet_serve": bench_fleet_serve.run,      # requeue-on-pilot-failure
    "fleet_serve_smoke": bench_fleet_serve.run_smoke,  # CI failure smoke
    "disagg": bench_disagg.run,        # split prefill/decode TTFT gate
    "disagg_smoke": bench_disagg.run_smoke,    # handoff bitwise+leak CI
    "autoscale": bench_autoscale.run,  # bursty demand vs peak-sized fleet
    "autoscale_smoke": bench_autoscale.run_smoke,  # ramp + scale-to-zero CI
    "chaos": bench_chaos.run,          # gray-failure drill, all gates
    "chaos_smoke": bench_chaos.run_smoke,  # kill+stall+hedged straggler CI
    "spec_decode": bench_spec_decode.run,          # draft-and-verify tok/s
    "spec_decode_smoke": bench_spec_decode.run_smoke,  # bitwise + accept CI
    "tp_serve": bench_tp_serve.run,    # SPMD sharded serving, full battery
    "tp_serve_smoke": bench_tp_serve.run_smoke,  # bitwise + 1-transfer CI
    "train": bench_train.run,          # payload-side training numbers
    "roofline": roofline.run,          # dry-run roofline aggregates
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of suites " + ",".join(SUITES))
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SUITES))
    print("name,value,detail")
    failures = 0
    for n in names:
        t0 = time.monotonic()
        try:
            rows = SUITES[n]()
        except Exception as e:                   # noqa: BLE001
            print(f"{n}_FAILED,nan,{type(e).__name__}: {e}")
            failures += 1
            continue
        for name, value, detail in rows:
            print(f'{name},{value:.6g},"{detail}"')
        print(f'{n}_suite_wall_s,{time.monotonic() - t0:.3f},""')
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
