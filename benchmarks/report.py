"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/ records.

  PYTHONPATH=src python -m benchmarks.report [--section dryrun|roofline]
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

NOTES = {
    "compute": "shard/skip FLOPs: causal-blocked attention, better TP fit",
    "memory": "fuse score/loss temporaries (Pallas flash), bf16, remat policy",
    "collective": "layout change (fsdp/EP), overlap, grad compression",
}


def load(tag):
    out = []
    for p in sorted((RESULTS / tag).glob("*.json")):
        if p.stem.count("__") > 1:
            continue
        out.append(json.loads(p.read_text()))
    return out


def dryrun_table(tag: str) -> str:
    rows = [
        "| cell | mode | compile s | flops/dev | fused GB/dev | coll GB/dev "
        "| AG/AR/RS/CP counts | args+out GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load(tag):
        hc = r["hlo_cost"]
        cc = hc["collective_counts"]
        counts = "/".join(str(int(cc.get(k, 0))) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "collective-permute"))
        mem = (r["memory"].get("argument_size_in_bytes", 0)
               + r["memory"].get("output_size_in_bytes", 0)) / 2**30
        rows.append(
            f'| {r["arch"]} x {r["shape"]} | {r["mode"]} '
            f'| {r["compile_seconds"]:.0f} '
            f'| {hc["flops"]:.3g} | {hc["bytes_fused"]/2**30:.1f} '
            f'| {hc["total_collective_bytes"]/2**30:.2f} | {counts} '
            f'| {mem:.2f} |')
    return "\n".join(rows)


def roofline_table(tag: str) -> str:
    rows = [
        "| cell | mode | compute s | memory s | collective s | dominant "
        "| useful | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(tag):
        t = r["roofline"]
        dom = t["dominant"].replace("_s", "")
        rows.append(
            f'| {r["arch"]} x {r["shape"]} | {r["mode"]} '
            f'| {t["compute_s"]:.3g} | {t["memory_s"]:.3g} '
            f'| {t["collective_s"]:.3g} | {dom} '
            f'| {t["useful_flops_ratio"]:.2f} '
            f'| {t["roofline_fraction"]:.4f} | {NOTES[dom]} |')
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section in ("dryrun", "all"):
        for tag, label in (("pod16x16", "single pod (16x16 = 256 chips)"),
                           ("pod2x16x16", "two pods (2x16x16 = 512 chips)")):
            print(f"\n### Dry-run — {label}\n")
            print(dryrun_table(tag))
    if args.section in ("roofline", "all"):
        print("\n### Roofline — single pod\n")
        print(roofline_table("pod16x16"))
        print("\n### Roofline — two pods\n")
        print(roofline_table("pod2x16x16"))


if __name__ == "__main__":
    main()
