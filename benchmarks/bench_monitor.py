"""Monitor scan overhead (paper §3.4) vs process-table size, plus
straggler-detection latency in scans."""

from __future__ import annotations

import time

from repro.core.monitor import Monitor, MonitorLimits
from repro.core.proctable import PAYLOAD_UID, ProcessTable


def run() -> list[tuple[str, float, str]]:
    out = []
    for n in (10, 100, 1000):
        pt = ProcessTable()
        for i in range(n):
            e = pt.register(PAYLOAD_UID, f"w{i}")
            pt.heartbeat(e.pid, 0.1)
        mon = Monitor(pt, MonitorLimits(max_wall=1e9),
                      fleet_median_fn=lambda: 0.1)
        t0 = time.monotonic()
        for _ in range(100):
            mon.scan()
        dt = (time.monotonic() - t0) / 100
        out.append((f"monitor_scan_us_n{n}", dt * 1e6, "per scan"))

    # straggler detection latency: scans until EWMA crosses 3x median
    pt = ProcessTable()
    e = pt.register(PAYLOAD_UID, "slow")
    mon = Monitor(pt, MonitorLimits(max_wall=1e9, straggler_factor=3.0),
                  fleet_median_fn=lambda: 0.1)
    scans = 0
    for step in range(100):
        pt.heartbeat(e.pid, 1.0)                 # 10x slower than fleet
        scans += 1
        if mon.scan():
            break
    out.append(("straggler_detect_scans", float(scans),
                "heartbeats until kill at 10x median"))

    # EWMA eviction: exited pids must not accumulate state across payloads
    pt = ProcessTable()
    mon = Monitor(pt, MonitorLimits(max_wall=1e9),
                  fleet_median_fn=lambda: 0.1)
    for i in range(1000):
        e = pt.register(PAYLOAD_UID, f"gen{i}")
        for _ in range(3):
            pt.heartbeat(e.pid, 0.1)
        mon.scan()
        pt.mark_exited(e.pid, 0)
    mon.scan()
    out.append(("monitor_ewma_entries_after_1k_payloads", float(len(mon._ewma)),
                "leak check: must stay O(live payloads)"))
    return out
