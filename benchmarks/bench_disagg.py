"""Disaggregated prefill/decode serving benchmark: TTFT under a
long-prompt + high-decode trace, with bitwise exactly-once gates.

The monolithic (unified) fleet holds a slot for a request's WHOLE
lifetime: one long prefill admission plus every decode step.  Under a
trace whose requests decode for ~25 steps each, queued prompts wait for
full-request slot turnover, so time-to-first-token grows with the decode
tail.  The disaggregated fleet splits the same pilot budget into a
prefill pool and a decode pool: prefill slots turn over per ADMISSION
(the KV handoff exports and the slot frees immediately), so the prompt
queue drains at prefill service rate regardless of decode length.  The
full run's trace decodes ~85 steps per request to make that contrast
real on the smoke-sized model.

Scenarios (equal total pilots, equal aggregate slots):

* ``unified`` — ``serve_fleet`` with 4 pilots x 2 slots.
* ``disagg``  — ``serve_disagg`` with 2 prefill + 2 decode pilots x 2
  slots, two-stage DisaggRouter, KV block handoff across pools.

Both must complete 100% of the trace with token streams BITWISE equal to
a single pre-warmed unified engine's (the handoff resume invariant), and
both block pools must audit to zero leaked blocks.  The run RAISES on a
drop, a mismatch, a leak, or the acceptance gate: the disaggregated
fleet must BEAT the unified fleet on p99 TTFT.

TTFT definitions match the architecture: unified TTFT is pool-level
submit-to-first-token; disagg TTFT is submit-to-prefill-export (the
first generated token exists at export and rides the handoff), and the
decode-stage import latency is reported separately as ``resume_p99_s``.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core.images import ExecutableRegistry
from repro.launch.serve import make_trace, serve_disagg, serve_fleet
from repro.models.api import build_model
from repro.serving.engine import ServeEngine

ARCH = "smollm-360m"
MAX_LEN = 64          # smoke: the standard mixed trace
BENCH_MAX_LEN = 128   # full run: room for ~85-step decode tails
SLOTS_PER_PILOT = 2
LEASE_TTL = 0.5


def _long_decode_trace(cfg, n_requests: int, seed: int = 0) -> list[dict]:
    """Long prompts (bucket 32) + ~85-step decode budgets: the workload
    shape where holding a slot through decode starves the prompt queue.
    bucket + budget <= max_len keeps every stream full-length."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        plen = int(rng.integers(20, 29))           # pow2 bucket -> 32
        out.append({"rid": i,
                    "prompt": rng.integers(
                        0, cfg.vocab_size, size=plen).tolist(),
                    "max_new_tokens": int(rng.choice([78, 84, 90])),
                    "at_step": i})
    return out


def _baseline_tokens(cfg, trace, slots: int, max_len: int = MAX_LEN) -> dict:
    """One pre-warmed unified engine, the whole trace: the bitwise
    reference both fleet topologies must reproduce."""
    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len)
    eng.warm_admission()
    eng.warm_install()
    eng.run_trace([{**e, "at_step": 0} for e in trace])
    return {rid: list(np.asarray(r.tokens).tolist())
            for rid, r in eng.done.items()}


def _check(label: str, n_requests: int, out: dict, base_tokens: dict):
    got = out["results"]
    if len(got) != n_requests:
        raise RuntimeError(
            f"{label} completed {len(got)}/{n_requests} requests")
    for rid, toks in got.items():
        if list(toks) != list(base_tokens[rid]):
            raise RuntimeError(
                f"{label}: rid {rid} token stream diverged from the "
                f"single-engine baseline (handoff resume not bitwise?)")
    if out.get("leaked_blocks", 0) != 0:
        raise RuntimeError(
            f"{label}: {out['leaked_blocks']} KV blocks leaked "
            f"(refcount imbalance across the handoff)")


def run(n_requests: int = 24) -> list[tuple[str, float, str]]:
    cfg = get_smoke_config(ARCH)
    trace = _long_decode_trace(cfg, n_requests, seed=0)
    base = _baseline_tokens(cfg, trace, 8, max_len=BENCH_MAX_LEN)

    registry = ExecutableRegistry()       # shared: role images key apart
    uni = serve_fleet(ARCH, n_requests, 4, slots=SLOTS_PER_PILOT,
                      max_len=BENCH_MAX_LEN, lease_ttl=LEASE_TTL,
                      registry=registry, trace=trace)
    uni["results"] = dict(uni["results"])
    _check("unified fleet", n_requests, uni, base)

    dis = serve_disagg(ARCH, n_requests, prefill_pilots=2, decode_pilots=2,
                       slots=SLOTS_PER_PILOT, max_len=BENCH_MAX_LEN,
                       lease_ttl=LEASE_TTL, registry=registry, trace=trace)
    _check("disagg fleet", n_requests, dis, base)
    if dis["prefills_exported"] < n_requests:
        raise RuntimeError(
            f"disagg exported {dis['prefills_exported']}/{n_requests} "
            f"prefills — requests bypassed the handoff path")

    speedup = (uni["ttft_p99_s"] / dis["ttft_p99_s"]
               if dis["ttft_p99_s"] else float("inf"))
    if speedup <= 1.0:
        raise RuntimeError(
            f"disagg p99 TTFT {dis['ttft_p99_s']:.3f}s did not beat the "
            f"unified fleet's {uni['ttft_p99_s']:.3f}s on the long-prompt "
            f"high-decode trace (gate: ratio > 1)")

    detail = (f"{ARCH}, 4 pilots x {SLOTS_PER_PILOT} slots each side, "
              f"{n_requests} reqs, ~85 decode steps each")
    return [
        ("disagg_token_match", 1.0,
         "both topologies bitwise == unified engine (raises otherwise)"),
        ("disagg_unified_ttft_p99_s", uni["ttft_p99_s"],
         f"monolithic fleet, {detail}"),
        ("disagg_ttft_p99_s", dis["ttft_p99_s"],
         "2 prefill + 2 decode pilots, TTFT = submit to prefill export"),
        ("disagg_ttft_p99_speedup", speedup,
         "unified p99 TTFT / disagg p99 TTFT (gate: > 1)"),
        ("disagg_ttft_p50_s", dis["ttft_p50_s"], "disagg median"),
        ("disagg_resume_p99_s", dis["resume_p99_s"],
         "handoff import latency: submit to decode-stage resume"),
        ("disagg_goodput_tok_per_s", dis["goodput_tok_per_s"], detail),
        ("disagg_unified_goodput_tok_per_s", uni["goodput_tok_per_s"],
         "monolithic fleet, same trace"),
        ("disagg_prefills_exported", float(dis["prefills_exported"]),
         f"of {n_requests} (every request crossed the handoff)"),
        ("disagg_handoffs_imported", float(dis["handoffs_imported"]),
         "decode-side imports (> exported only under replay)"),
        ("disagg_leaked_blocks", float(dis["leaked_blocks"]),
         "block-pool audit across both pools (gate: 0)"),
    ]


def run_smoke(n_requests: int = 10) -> list[tuple[str, float, str]]:
    """CI smoke: the smallest disaggregated fleet (1 prefill + 1 decode
    pilot) over a mixed trace — gates bitwise parity with the unified
    engine, 100% completion through the handoff, and zero leaked
    blocks."""
    cfg = get_smoke_config(ARCH)
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN, seed=0)
    base = _baseline_tokens(cfg, trace, 4)
    dis = serve_disagg(ARCH, n_requests, prefill_pilots=1, decode_pilots=1,
                       slots=SLOTS_PER_PILOT, max_len=MAX_LEN,
                       lease_ttl=LEASE_TTL, registry=ExecutableRegistry(),
                       trace=trace)
    _check("disagg smoke", n_requests, dis, base)
    if not dis["drained"]:
        raise RuntimeError("disagg router did not drain")
    if dis["prefills_exported"] < n_requests:
        raise RuntimeError(
            f"exported {dis['prefills_exported']}/{n_requests} prefills")
    return [
        ("disagg_smoke_completed", float(len(dis["results"])),
         f"of {n_requests}, 1 prefill + 1 decode pilot"),
        ("disagg_smoke_token_match", 1.0,
         "streams bitwise == unified single-engine baseline"),
        ("disagg_smoke_exported", float(dis["prefills_exported"]),
         "prefill-side KV handoff exports"),
        ("disagg_smoke_imported", float(dis["handoffs_imported"]),
         "decode-side KV handoff imports"),
        ("disagg_smoke_leaked_blocks", float(dis["leaked_blocks"]),
         "block-pool audit (gate: 0)"),
        ("disagg_smoke_ttft_p99_s", dis["ttft_p99_s"],
         "submit to prefill export, incl. queue wait"),
    ]
