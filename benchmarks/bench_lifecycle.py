"""Pilot lifecycle step costs (paper Fig. 2, steps a-h).

Times each conceptual step of one pilot serving one training payload:
(a) start/validate, (b) match, (c) bind+stage+publish, (d+) payload run,
(e) collect, (f) cleanup, (h) terminate.
"""

from __future__ import annotations

import time

from repro.core.arena import SharedArena
from repro.core.images import ExecutableRegistry, PayloadImage
from repro.core.latebind import PayloadExecutor, PodPatchCapability
from repro.core.proctable import PAYLOAD_UID, ProcessTable
from repro.core.taskrepo import TaskRepo


def run() -> list[tuple[str, float, str]]:
    out = []
    repo = TaskRepo()
    reg = ExecutableRegistry()
    img = PayloadImage("smollm-360m", "smoke", "train")
    repo.submit(img, n_steps=3)

    t = time.monotonic()
    arena = SharedArena()
    pt = ProcessTable()
    ex = PayloadExecutor("pod-l", arena, pt, reg)
    out.append(("a_start_s", time.monotonic() - t, "arena+placeholder"))

    t = time.monotonic()
    task = repo.match_wait({"pilot_id": "bench", "labels": {}}, timeout=1.0)
    out.append(("b_match_s", time.monotonic() - t, "matchmaking (indexed)"))

    t = time.monotonic()
    ex.patch_image(PodPatchCapability("pod-l"), task.image)
    arena.write_env({"seed": 0})
    ex.start(spec_timeout=10.0)
    arena.publish_startup_spec({"n_steps": task.n_steps})
    out.append(("c_bind_stage_s", time.monotonic() - t,
                "pod patch + stage + publish spec"))

    t = time.monotonic()
    ex.wait_exit(timeout=300.0)          # park on the exit event, no polling
    out.append(("d_payload_run_s", time.monotonic() - t,
                f"{task.n_steps} train steps incl. jit"))

    t = time.monotonic()
    exit_info = arena.read_exit()
    out.append(("e_collect_s", time.monotonic() - t,
                f"exit={exit_info['exitcode']}"))

    t = time.monotonic()
    ex.reset()
    arena.wipe_shared()
    out.append(("f_cleanup_s", time.monotonic() - t,
                "executor reset + volume wipe"))

    t = time.monotonic()
    pt.kill_uid(PAYLOAD_UID)
    arena.destroy()
    out.append(("h_terminate_s", time.monotonic() - t, "arena destroy"))
    return out
