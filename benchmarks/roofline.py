"""Assemble the §Roofline table from the dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.roofline [--multi-pod] [--md]

Reads results/dryrun/<mesh>/*.json (produced by repro.launch.dryrun) and
emits the per-cell three-term roofline with the dominant bottleneck,
MODEL_FLOPS ratio and a one-line "what would move the dominant term" note.
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

_NOTES = {
    ("compute_s",): "more TP/FSDP sharding or causal-block FLOP skipping",
    ("memory_s",): "fuse attention/loss temporaries (Pallas), bf16 remat, "
                   "smaller chunks",
    ("collective_s",): "overlap FSDP all-gathers with layer compute; "
                       "int8-compress DP grads; EP instead of TP for MoE",
}


def load(mesh_tag: str) -> list[dict]:
    recs = []
    d = RESULTS / mesh_tag
    for p in sorted(d.glob("*.json")):
        if "__" in p.stem and p.stem.count("__") > 1:
            continue                      # flag-variant records (see §Perf)
        recs.append(json.loads(p.read_text()))
    return recs


def note_for(rec: dict) -> str:
    return _NOTES.get((rec["roofline"]["dominant"],), "")


def rows(mesh_tag: str) -> list[dict]:
    out = []
    for r in load(mesh_tag):
        t = r["roofline"]
        coll = r.get("hlo_cost", {}).get("collective_counts", {})
        out.append({
            "cell": f'{r["arch"]} x {r["shape"]}',
            "mode": r["mode"],
            "compute_s": t["compute_s"],
            "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "dominant": t["dominant"].replace("_s", ""),
            "useful_ratio": t["useful_flops_ratio"],
            "roofline_frac": t["roofline_fraction"],
            "mem_GB": (r["memory"].get("argument_size_in_bytes", 0)
                       + r["memory"].get("output_size_in_bytes", 0)) / 2**30,
            "coll_counts": {k: int(v) for k, v in coll.items()},
            "note": note_for(r),
        })
    return out


def run() -> list[tuple[str, float, str]]:
    """benchmarks.run entry: aggregate stats over the single-pod table."""
    rs = rows("pod16x16")
    if not rs:
        return [("roofline_cells", 0.0, "run repro.launch.dryrun --all first")]
    dom = {}
    for r in rs:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    out = [("roofline_cells", float(len(rs)), "single-pod")]
    for k, v in sorted(dom.items()):
        out.append((f"roofline_dominant_{k}", float(v), "cells"))
    best = max(rs, key=lambda r: r["roofline_frac"] or 0)
    worst = min(rs, key=lambda r: r["roofline_frac"] or 1)
    out.append(("roofline_frac_best", best["roofline_frac"], best["cell"]))
    out.append(("roofline_frac_worst", worst["roofline_frac"], worst["cell"]))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--md", action="store_true", help="markdown table")
    args = ap.parse_args()
    tag = "pod2x16x16" if args.multi_pod else "pod16x16"
    rs = rows(tag)
    if args.md:
        print("| cell | mode | compute s | memory s | collective s | "
              "dominant | useful | frac |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rs:
            print(f'| {r["cell"]} | {r["mode"]} | {r["compute_s"]:.3g} | '
                  f'{r["memory_s"]:.3g} | {r["collective_s"]:.3g} | '
                  f'{r["dominant"]} | {r["useful_ratio"]:.2f} | '
                  f'{(r["roofline_frac"] or 0):.4f} |')
    else:
        for r in rs:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
