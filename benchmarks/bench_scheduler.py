"""Overlay-scheduler throughput: N pilots draining M noop payloads.

Measures matchmaking + lease + completion overhead of the TaskRepo with
concurrent pilots — the control-plane cost per payload, which bounds how
small a task can be before scheduling dominates (dHTC sizing rule).

With the event-driven control plane the interesting numbers are:

* ``sched_overhead_ms_per_task`` — pilot-seconds burned per payload
  (wall x fleet / tasks, the seed's definition);
* ``sched_cpu_ms_per_task`` — process CPU consumed per payload.  This is
  the honest scale metric in a single-interpreter simulation: wall-based
  pilot-seconds at 32 in-process pilots mostly count GIL serialization of
  payload execution, which a real fleet (one pilot per node) never pays.
  Control-plane CPU per task staying flat from 4 -> 32 pilots is the
  sub-linear-growth result;
* ``sched_match_p50_us`` / ``sched_match_p99_us`` — matchmaking cost under
  the repo lock (indexed heaps: O(log n + predicates), not a queue scan);
* ``sched_idle_wakeups`` — condition-variable wakeups that found no work
  (idle-CPU proxy; a polling scheduler's equivalent grows with wall time,
  an event-driven one stays near the contention level);
* the ``sched32_*`` family — the same per-pilot load at 32 pilots.
"""

from __future__ import annotations

import resource
import threading
import time

from repro.analysis.locks import LockAuditor, make_lock
from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig


def _run_one(prefix: str, n_pilots: int, n_tasks: int
             ) -> list[tuple[str, float, str]]:
    sim = ClusterSim()
    noop = PayloadImage(arch="placeholder", shape="none", mode="noop")
    # warm the one-time XLA compiles (image pull + PRNG key) before the
    # clock starts: image-pull cost is bench_bind's subject; this suite
    # measures steady-state control-plane overhead per task
    sim.registry.pull(noop)
    from repro.core.wrapper import _seed_key
    _seed_key(0)
    for _ in range(n_tasks):
        sim.repo.submit(noop, n_steps=1)
    r0 = resource.getrusage(resource.RUSAGE_SELF)
    t0 = time.monotonic()
    # the seed pinned monitor_interval=0.002 because payload collection
    # latency rode on the poll tick; collection is event-driven now, so the
    # default (50 ms) wall/straggler tick is plenty
    fleet = sim.spawn_fleet(n_pilots, PilotConfig(
        max_payloads=n_tasks, idle_grace=0.3))
    ok = fleet.await_drained(timeout=120.0)
    wall = time.monotonic() - t0
    r1 = resource.getrusage(resource.RUSAGE_SELF)
    fleet.join_all(10.0)
    done = sim.repo.stats()["done"]
    cpu = (r1.ru_utime - r0.ru_utime) + (r1.ru_stime - r0.ru_stime)
    m = sim.repo.scheduler_metrics()
    return [
        (f"{prefix}_tasks_done", float(done), f"of {n_tasks}, drained={ok}"),
        (f"{prefix}_wall_s", wall, f"{n_pilots} pilots"),
        (f"{prefix}_tasks_per_s", done / wall, "throughput"),
        (f"{prefix}_overhead_ms_per_task", 1e3 * wall * n_pilots / max(done, 1),
         "pilot-seconds per payload"),
        (f"{prefix}_cpu_ms_per_task", 1e3 * cpu / max(done, 1),
         "process CPU per payload (flat across fleet sizes = sub-linear)"),
        (f"{prefix}_match_p50_us", m["match_p50_us"], "indexed match, lock held"),
        (f"{prefix}_match_p99_us", m["match_p99_us"], "indexed match, lock held"),
        (f"{prefix}_idle_wakeups", float(m["idle_wakeups"]),
         "cond wakeups that found no work"),
    ]


def _lockop_ns(make) -> float:
    """Mean acquire+release cost (ns) for a lock from ``make()``."""
    lk = make()
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with lk:
            pass
    return (time.perf_counter() - t0) / n * 1e9


def run(n_pilots: int = 4, n_tasks: int = 40) -> list[tuple[str, float, str]]:
    out = _run_one("sched", n_pilots, n_tasks)
    # scale point: same per-pilot load (10 tasks/pilot) at 8x the fleet —
    # control-plane CPU per task must grow sub-linearly in fleet size
    per_pilot = max(1, n_tasks // max(n_pilots, 1))
    out += _run_one("sched32", 32, 32 * per_pilot)

    # ---- concurrency-audit overhead: instrumented-vs-off ------------------
    # The same fleet run under a full LockAuditor (every acquisition graphed)
    # plus a microbench gating the AUDIT-OFF tax: a TrackedLock with no
    # auditor installed costs one extra attr read over a raw threading.Lock;
    # scaled by the run's observed lock ops per task it must stay <= 2% of
    # sched_overhead_ms_per_task.
    aud = LockAuditor()
    aud.install()
    try:
        out += _run_one("sched_audit", n_pilots, n_tasks)
    finally:
        aud.uninstall()
    rep = aud.report()
    assert not rep["cycles"], f"lock-order cycles under audit: {rep['cycles']}"
    assert not rep["violations"], (
        f"auditor violations under audit: {rep['violations']}")
    ops_per_task = aud.acquired_total / max(n_tasks, 1)
    raw_ns = _lockop_ns(threading.Lock)
    off_ns = _lockop_ns(lambda: make_lock("bench.lockop"))
    base_ms = next(v for k, v, _ in out if k == "sched_overhead_ms_per_task")
    overhead_pct = (max(0.0, off_ns - raw_ns) * ops_per_task
                    / (base_ms * 1e6) * 100.0)
    assert overhead_pct <= 2.0, (
        f"audit-off lock overhead {overhead_pct:.3f}% of scheduler "
        f"overhead exceeds the 2% budget "
        f"(raw={raw_ns:.0f}ns tracked-off={off_ns:.0f}ns "
        f"ops/task={ops_per_task:.0f})")
    out += [
        ("sched_lock_ops_per_task", ops_per_task,
         "tracked acquisitions per payload (audited run)"),
        ("sched_lockop_raw_ns", raw_ns, "threading.Lock acquire+release"),
        ("sched_lockop_off_ns", off_ns,
         "TrackedLock acquire+release, auditor off"),
        ("sched_audit_off_overhead_pct", overhead_pct,
         "audit-off tax vs sched_overhead_ms_per_task (gate <= 2%)"),
        ("sched_audit_order_edges", float(rep["n_edges"]),
         "lock-order edges observed; cycles/violations gated at 0"),
    ]
    return out
