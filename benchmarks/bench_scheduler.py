"""Overlay-scheduler throughput: N pilots draining M noop payloads.

Measures matchmaking + lease + completion overhead of the TaskRepo with
concurrent pilots — the control-plane cost per payload, which bounds how
small a task can be before scheduling dominates (dHTC sizing rule)."""

from __future__ import annotations

import time

from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig


def run(n_pilots: int = 4, n_tasks: int = 40) -> list[tuple[str, float, str]]:
    sim = ClusterSim()
    noop = PayloadImage(arch="placeholder", shape="none", mode="noop")
    for _ in range(n_tasks):
        sim.repo.submit(noop, n_steps=1)
    t0 = time.monotonic()
    for s in sim.provision(n_pilots):
        sim.spawn_pilot(s, PilotConfig(max_payloads=n_tasks, idle_grace=0.3,
                                       monitor_interval=0.002))
    ok = sim.run_until_drained(timeout=120.0, poll=0.01)
    wall = time.monotonic() - t0
    sim.join_all(10.0)
    done = sim.repo.stats()["done"]
    return [
        ("sched_tasks_done", float(done), f"of {n_tasks}, drained={ok}"),
        ("sched_wall_s", wall, f"{n_pilots} pilots"),
        ("sched_tasks_per_s", done / wall, "throughput"),
        ("sched_overhead_ms_per_task", 1e3 * wall * n_pilots / max(done, 1),
         "pilot-seconds per payload"),
    ]
