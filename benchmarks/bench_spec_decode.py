"""Speculative-decoding benchmark: draft-and-verify multi-token steps
against the plain one-token decode loop, on the same trace.

The workload is REPEATED TRAFFIC — a fixed set of hot prompts served
over and over (shared system prompts / repeated queries, the same
regime ``bench_serving``'s prefix-reuse rows model).  The draft is a
narrow decoder DISTILLED on the target's past rollouts of that traffic:
the bench serves the hot set once with the target, teacher-forces the
draft onto those greedy continuations (left-padded exactly as admission
pads them), then times a fresh trace.  This is the production shape of
speculative serving: the drafter is trained on yesterday's traffic and
verified token-by-token against today's target outputs.

Engines under test:

* ``off``       — the PR-2 continuous-batching loop, paged KV, one
  token (and one device->host transfer) per step.  The baseline.
* ``distilled`` — ``spec="draft"`` with the distilled draft.  A draft
  forward is a fraction of the target's, so every accepted token is
  nearly free, and k+1 tokens ride ONE packed transfer + one host
  scheduling pass.  Headline row (target >= 1.5x tok/s).
* ``mixed``     — the distilled draft on traffic diluted with novel
  prompts the draft has never seen: acceptance collapses on the novel
  slots, which decode one token per step and hog the step budget —
  the honest picture of how spec decoding degrades off-distribution.
* ``self``      — the target drafting for itself (ablation): acceptance
  is as high as numerics allow but each draft token costs a full target
  forward, so this isolates transfer/host amortization with zero
  compute savings.
* ``random``    — an untrained draft (ablation): near-zero acceptance
  shows the misprediction floor — the verify forward always commits at
  least one target token per step, so ``tokens_per_step`` never drops
  below the plain loop's.

Token streams from every spec engine must be bitwise identical to the
baseline; the bench RAISES on mismatch, on a broken one-transfer
invariant (``d2h_transfers != decode_steps``), and on leaked blocks
after the trace drains (speculation must not allocate).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.models.api import build_model
from repro.optim.adamw import OptimConfig, adamw_update, init_opt_state
from repro.serving.engine import ServeEngine, admit_length

MAX_LEN = 96
N_HOT = 8
ROLLOUT_BUDGET = 60       # past-traffic budget; eval budgets stay below
DISTILL_STEPS = 500


def _bench_config(arch: str):
    """The smoke configs are deliberately tiny (d_model 60) — at that
    size a draft forward costs nearly as much as a target forward and
    speculation can only amortize host overhead.  Scale the target so
    the draft/target compute gap is the one any real deployment has."""
    cfg = get_smoke_config(arch)
    return dataclasses.replace(
        cfg, name=cfg.name + "-spec-bench", d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=2048)


def _draft_config(cfg):
    """A draft a fraction of the target's width and depth.  Must share
    the target's vocab (verify compares argmax ids directly)."""
    return dataclasses.replace(
        cfg, name=cfg.name + "-draft", d_model=32, num_heads=2,
        num_kv_heads=1, head_dim=16, d_ff=64, num_layers=2)


def _hot_prompts(cfg):
    rng = np.random.default_rng(42)
    return [rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(8, 25))).tolist()
            for _ in range(N_HOT)]


def _distill(cfg, params, dcfg, hot, *, slots):
    """Serve the hot set once (past traffic), then teacher-force the
    draft onto the target's greedy continuations.  Prompts are
    left-padded to their admit bucket, exactly as the engine pads them
    at admission — the draft must see the contexts it will serve."""
    trace = [{"rid": i, "prompt": p, "max_new_tokens": ROLLOUT_BUDGET,
              "at_step": i} for i, p in enumerate(hot)]
    eng = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                      kv="paged")
    eng.run_trace(trace)
    seqs = [(hot[i], list(eng.done[i].tokens)) for i in eng.done]

    L = MAX_LEN - 1
    toks = np.zeros((len(seqs), L), np.int32)
    tgts = np.zeros((len(seqs), L), np.int32)
    mask = np.zeros((len(seqs), L), np.float32)
    for i, (p, c) in enumerate(seqs):
        plen = admit_length(len(p), MAX_LEN)
        full = ([0] * (plen - len(p)) + p + c)[:L + 1]
        toks[i, :len(full) - 1] = full[:-1]
        tgts[i, :len(full) - 1] = full[1:]
        mask[i, plen - 1:len(full) - 1] = 1.0

    def loss_fn(p, b):
        l, _ = tf.lm_loss(p, dcfg, b["tokens"], b["targets"],
                          loss_mask=b["mask"], compute=jnp.float32)
        return l

    oc = OptimConfig(peak_lr=3e-3, warmup_steps=50,
                     total_steps=DISTILL_STEPS, weight_decay=0.0)

    @jax.jit
    def train_step(p, opt, b):
        l, g = jax.value_and_grad(loss_fn)(p, b)
        p, opt, _ = adamw_update(p, g, opt, oc)
        return p, opt, l

    dparams = build_model(dcfg).init(jax.random.key(1))
    opt = init_opt_state(dparams)
    batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts),
             "mask": jnp.asarray(mask)}
    t0 = time.monotonic()
    for _ in range(DISTILL_STEPS):
        dparams, opt, l = train_step(dparams, opt, batch)
    return dparams, float(l), time.monotonic() - t0, len(seqs)


def _repeat_trace(hot, n=16, seed=0):
    r = np.random.default_rng(seed)
    return [{"rid": i, "prompt": list(hot[int(r.integers(len(hot)))]),
             "max_new_tokens": int(r.choice([40, 48, 56])), "at_step": i}
            for i in range(n)]


def _mixed_trace(cfg, hot, n=18, seed=1):
    """2/3 repeated traffic, 1/3 prompts the draft has never seen."""
    r = np.random.default_rng(seed)
    out = []
    for i in range(n):
        if i % 3 < 2:
            prompt = list(hot[int(r.integers(len(hot)))])
        else:
            prompt = r.integers(0, cfg.vocab_size,
                                size=int(r.integers(4, 20))).tolist()
        out.append({"rid": i, "prompt": prompt,
                    "max_new_tokens": int(r.choice([16, 24, 32])),
                    "at_step": i})
    return out


def _tokens_by_rid(eng) -> dict:
    return {rid: tuple(r.tokens) for rid, r in eng.done.items()}


def _assert_invariants(eng, stats, base_tokens, label):
    if _tokens_by_rid(eng) != base_tokens:
        bad = [r for r in base_tokens
               if _tokens_by_rid(eng).get(r) != base_tokens[r]]
        raise RuntimeError(
            f"spec-vs-off token mismatch ({label}): rids {bad[:4]}")
    if stats["d2h_transfers"] != stats["decode_steps"]:
        raise RuntimeError(
            f"one-transfer invariant broken ({label}): "
            f"{stats['d2h_transfers']} transfers over "
            f"{stats['decode_steps']} steps")
    # speculation must not allocate: after the trace drains, the only
    # live blocks are prefix-cache pins, and flushing those frees all
    if eng.allocator is not None:
        if eng.allocator.allocated_blocks != len(eng.prefix._map):
            raise RuntimeError(
                f"block leak ({label}): {eng.allocator.allocated_blocks} "
                f"allocated vs {len(eng.prefix._map)} prefix pins")
        eng.prefix.evict_unreferenced(eng.allocator.capacity_blocks)
        if eng.allocator.allocated_blocks != 0:
            raise RuntimeError(f"block leak after flush ({label})")


_WARM_TRACE = [{"rid": 900 + i, "prompt": list(range(2, 2 + n)),
                "max_new_tokens": 4, "at_step": 0}
               for i, n in enumerate((6, 20))]


def _timed_run(eng, trace):
    """Warm every admit bucket AND the (spec) step functions before the
    timed region — a cold draft/verify jit would otherwise be billed to
    the first measured step."""
    eng.warm_admission()
    eng.run_trace([dict(e) for e in _WARM_TRACE])
    eng.reset_metrics()
    return eng.run_trace(trace)


def _spec_engine(cfg, params, *, slots, spec_k, draft=None):
    kw = {}
    if draft is not None:
        kw["draft_cfg"], kw["draft_params"] = draft
    return ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       kv="paged", spec="draft", spec_k=spec_k, **kw)


def run(arch: str = "smollm-360m", slots: int = 4,
        spec_k: int = 8) -> list[tuple[str, float, str]]:
    cfg = _bench_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    dcfg = _draft_config(cfg)
    hot = _hot_prompts(cfg)
    dparams, dloss, dtrain_s, nseq = _distill(cfg, params, dcfg, hot,
                                              slots=slots)
    rep = _repeat_trace(hot)
    mix = _mixed_trace(cfg, hot)

    engo = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       kv="paged")
    off = _timed_run(engo, rep)
    base = _tokens_by_rid(engo)

    engo_m = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                         kv="paged")
    off_m = _timed_run(engo_m, mix)
    base_m = _tokens_by_rid(engo_m)

    def spec_run(label, trace, base_tokens, off_stats, *, k, draft):
        eng = _spec_engine(cfg, params, slots=slots, spec_k=k,
                           draft=draft)
        stats = _timed_run(eng, trace)
        _assert_invariants(eng, stats, base_tokens, label)
        stats["ratio"] = (stats["tok_per_s"] / off_stats["tok_per_s"]
                          if off_stats["tok_per_s"] else float("inf"))
        return stats

    dist = spec_run("distilled", rep, base, off, k=spec_k,
                    draft=(dcfg, dparams))
    dist_lo = spec_run("distilled-lo", rep, base, off,
                       k=max(2, spec_k // 2), draft=(dcfg, dparams))
    mixed = spec_run("mixed", mix, base_m, off_m, k=spec_k,
                     draft=(dcfg, dparams))
    slf = spec_run("self-draft", rep, base, off, k=4, draft=None)
    rnd = spec_run("random-draft", rep, base, off, k=spec_k,
                   draft=(dcfg, build_model(dcfg).init(jax.random.key(7))))

    detail = f"{arch} scaled, {slots} slots, k={spec_k}, repeated traffic"
    return [
        ("spec_tok_per_s", dist["tok_per_s"],
         detail + " (distilled draft)"),
        ("spec_off_tok_per_s", off["tok_per_s"], detail + " (spec off)"),
        ("spec_vs_off_tok_ratio", dist["ratio"],
         "distilled draft / off tok/s (target >= 1.5, tokens bitwise "
         "equal)"),
        ("spec_acceptance_rate", dist["acceptance_rate"],
         f"accepted / drafted; distilled on {nseq} past rollouts, "
         f"final CE {dloss:.2g}"),
        ("spec_tokens_per_step", dist["tokens_per_step"],
         "committed tokens per decode step (1 per live slot when off)"),
        ("spec_decode_steps", float(dist["decode_steps"]),
         f"vs {off['decode_steps']} steps with spec off"),
        ("spec_d2h_per_step",
         dist["d2h_transfers"] / dist["decode_steps"]
         if dist["decode_steps"] else 0.0,
         "device->host transfers per step (must be 1; k+1 tokens ride "
         "it)"),
        ("spec_draft_overhead_s", dist["draft_overhead_s"],
         "wall time inside the draft scan"),
        ("spec_distill_train_s", dtrain_s,
         f"{DISTILL_STEPS} teacher-forced steps, one-time cost"),
        ("spec_token_match", 1.0,
         "every spec engine bitwise == off (raises otherwise)"),
        ("spec_k_half_tok_ratio", dist_lo["ratio"],
         f"distilled draft at k={max(2, spec_k // 2)}"),
        ("spec_mixed_tok_ratio", mixed["ratio"],
         "1/3 novel prompts: novel slots decode 1 tok/step and dilute "
         "the win"),
        ("spec_mixed_acceptance", mixed["acceptance_rate"],
         "acceptance under off-distribution dilution"),
        ("spec_self_draft_tok_ratio", slf["ratio"],
         "self-draft k=4: transfer amortization only, each draft token "
         "costs a full target forward"),
        ("spec_self_draft_acceptance", slf["acceptance_rate"],
         "acceptance ceiling (limited only by S=1 vs S=k+1 numerics)"),
        ("spec_random_draft_acceptance", rnd["acceptance_rate"],
         "untrained draft: the acceptance floor"),
        ("spec_random_draft_tokens_per_step", rnd["tokens_per_step"],
         "never below 1/slot: verify always commits one target token"),
    ]


def run_smoke(arch: str = "smollm-360m") -> list[tuple[str, float, str]]:
    """CI smoke: a short trace through spec="draft" (self-draft — no
    training in CI) and the baseline; RAISES on token mismatch,
    acceptance_rate == 0, a broken one-transfer invariant, or leaked
    blocks after the trace drains."""
    from repro.launch.serve import make_trace
    cfg = get_smoke_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    trace = make_trace(cfg.vocab_size, 6, max_len=MAX_LEN, stagger=2,
                       seed=3)

    engo = ServeEngine(cfg, params, slots=2, max_len=MAX_LEN, kv="paged")
    off = engo.run_trace(trace)
    base = _tokens_by_rid(engo)

    engs = ServeEngine(cfg, params, slots=2, max_len=MAX_LEN, kv="paged",
                       spec="draft", spec_k=4)
    spec = engs.run_trace([dict(e) for e in trace])
    _assert_invariants(engs, spec, base, "smoke self-draft")
    if spec["acceptance_rate"] <= 0.0:
        raise RuntimeError("smoke acceptance_rate is zero — the draft "
                           "scan or the verify accept mask is broken")
    return [
        ("spec_smoke_token_match", 1.0,
         "spec bitwise == off on the smoke trace"),
        ("spec_smoke_acceptance_rate", spec["acceptance_rate"],
         "self-draft, must be > 0"),
        ("spec_smoke_tokens_per_step", spec["tokens_per_step"],
         f"vs 1/slot over {off['decode_steps']} baseline steps"),
        ("spec_smoke_d2h_per_step",
         spec["d2h_transfers"] / spec["decode_steps"]
         if spec["decode_steps"] else 0.0,
         "one packed transfer per step"),
        ("spec_smoke_completed", float(spec["completed"]), "of 6"),
    ]
