"""Chaos benchmark: gray-failure drills against the hardened serving fleet.

A :class:`~repro.core.chaos.ChaosController` runs a scripted
:class:`~repro.core.chaos.FaultPlan` — hard crash, stall (renewing but
frozen), slow straggler, flaky heartbeats, control-plane partition, and a
poison request that kills every pilot that fetches it — against a
FleetDispatcher fleet with the full :class:`RobustnessPolicy` hardening on
(progress watchdog, hedged re-dispatch, backoff requeue, blast-radius
quarantine).

Gates (the run RAISES on violation):

* 100% completion of the NON-POISON requests;
* committed tokens bitwise-identical to a single-engine no-chaos baseline
  (greedy decode + first-completion-wins keeps replay/hedge exactly-once);
* p99 pool TTFT <= 3x the no-chaos fleet run;
* the poison request quarantined after at most 2 pilot kills, with ZERO
  false positives (nothing else quarantined);
* zero KV block-pool leaks across every gracefully-exited server.

``run_smoke`` is the CI variant: one kill + one stall + one hedged slow
straggler, with the completion + bitwise + leak gates and a hedge
actually fired.
"""

from __future__ import annotations

from benchmarks.bench_fleet_serve import _baseline
from repro.configs.base import get_smoke_config
from repro.core.chaos import FaultPlan, FaultSpec
from repro.core.images import ExecutableRegistry
from repro.core.taskrepo import BackoffPolicy
from repro.launch.serve import make_trace, serve_fleet
from repro.serving.dispatch import RobustnessPolicy

ARCH = "smollm-360m"
MAX_LEN = 64
SLOTS_PER_PILOT = 2
LEASE_TTL = 0.4


def _policy() -> RobustnessPolicy:
    """Drill-tuned hardening: deadlines and budgets scaled to smoke-model
    request service times (~0.1-0.5 s), so every detection layer can fire
    within a short trace."""
    return RobustnessPolicy(
        stall_deadline=0.5, sick_cooldown=1.0,
        # p50-based straggler budget: a handful of slow-server completions
        # in a short drill would blow a p95 budget sky-high; the median
        # stays anchored to healthy service
        hedging=True, hedge_percentile=50.0, hedge_min_s=0.3,
        hedge_factor=3.0, hedge_min_samples=4, watchdog_interval=0.05,
        max_hedges=2, bench_after_hedges=2,
        quarantine_after=2,
        backoff=BackoffPolicy(base=0.1, cap=0.5))


def _check_tokens(label: str, out: dict, base_tokens: dict,
                  n_requests: int):
    if out["completed"] != n_requests:
        raise RuntimeError(
            f"chaos run {label} completed {out['completed']}/{n_requests} "
            f"non-poison requests — the hardening lost work")
    for rid, toks in out["results"].items():
        if list(toks) != list(base_tokens[rid]):
            raise RuntimeError(
                f"chaos run {label}: rid {rid} token stream diverged from "
                f"the no-chaos baseline — replay/hedge broke determinism")
    if out["leaked_blocks"] != 0:
        raise RuntimeError(
            f"chaos run {label} leaked {out['leaked_blocks']} KV pool "
            f"blocks — a cancel/hedge/revoke path dropped refcounts")


def _check_quarantine(out: dict):
    if sorted(out["quarantined_rids"]) != sorted(out["poison_rids"]):
        raise RuntimeError(
            f"quarantine mismatch: quarantined {out['quarantined_rids']} "
            f"vs poison {out['poison_rids']} — false positive or an "
            f"unquarantined poison")
    kills = (out.get("chaos") or {}).get("poison_kills", {})
    for rid, n in kills.items():
        if n > 2:
            raise RuntimeError(
                f"poison rid {rid} killed {n} pilots before quarantine "
                f"(gate: <= 2)")


def run(n_requests: int = 48, n_pilots: int = 6) -> list[tuple[str, float, str]]:
    cfg = get_smoke_config(ARCH)
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN, seed=0)
    base = _baseline(cfg, trace, n_pilots * SLOTS_PER_PILOT)

    registry = ExecutableRegistry()       # shared: scenarios reuse compiles
    # reference: the hardened fleet with NO faults — the TTFT the chaos
    # run is judged against (hardening on in both, chaos is the variable)
    ref = serve_fleet(ARCH, n_requests, n_pilots, slots=SLOTS_PER_PILOT,
                      max_len=MAX_LEN, lease_ttl=LEASE_TTL,
                      registry=registry, robustness=_policy())
    _check_tokens("no-chaos", ref, base["tokens"], n_requests)

    # the mixed drill: every fault kind, timed to land while the trace is
    # in flight (the smoke model serves a request in ~0.1-0.3 s, so the
    # whole window is the first half second), plus one poison request
    plan = FaultPlan(faults=[
        FaultSpec(kind="slow", at_s=0.10, duration_s=1.2, factor=20.0),
        FaultSpec(kind="crash", at_s=0.15),
        FaultSpec(kind="stall", at_s=0.20, duration_s=1.2),
        FaultSpec(kind="flaky_heartbeat", at_s=0.20, duration_s=2.0,
                  drop_rate=0.75),
        FaultSpec(kind="partition", at_s=0.35, duration_s=0.6),
    ], poison=True)
    out = serve_fleet(ARCH, n_requests, n_pilots, slots=SLOTS_PER_PILOT,
                      max_len=MAX_LEN, lease_ttl=LEASE_TTL,
                      registry=registry, robustness=_policy(),
                      chaos_plan=plan, poison=1)
    _check_tokens("mixed", out, base["tokens"], n_requests)
    _check_quarantine(out)

    ratio = (out["ttft_p99_s"] / ref["ttft_p99_s"]
             if ref["ttft_p99_s"] else float("inf"))
    if ratio > 3.0:
        raise RuntimeError(
            f"chaos pushed p99 TTFT to {ratio:.2f}x the no-chaos fleet "
            f"run (gate: <= 3x)")

    detail = (f"{ARCH}, {n_pilots} pilots x {SLOTS_PER_PILOT} slots, "
              f"{n_requests} reqs + 1 poison, lease_ttl {LEASE_TTL}s")
    faults_applied = float((out.get("chaos") or {}).get("faults_applied", 0))
    return [
        ("chaos_completed", float(out["completed"]),
         f"of {n_requests} non-poison ({detail})"),
        ("chaos_token_match", 1.0,
         "chaos-run tokens bitwise == no-chaos baseline (raises otherwise)"),
        ("chaos_ttft_p99_ratio", ratio,
         "chaos p99 TTFT / no-chaos fleet p99 TTFT (gate: <= 3)"),
        ("chaos_faults_applied", faults_applied,
         "crash+stall+slow+flaky+partition+poison landed"),
        ("chaos_quarantined", float(out["quarantined"]),
         "poison requests settled by blast-radius accounting (= poison count)"),
        ("chaos_poison_kills", float(sum(
            (out.get("chaos") or {}).get("poison_kills", {}).values())),
         "pilots the poison killed before quarantine (gate: <= 2)"),
        ("chaos_hedges", float(out["hedges"]),
         "hedged duplicate dispatches (stragglers raced)"),
        ("chaos_stalls_revoked", float(out["stalls_revoked"]),
         "renewing-but-frozen requests revoked by the progress watchdog"),
        ("chaos_replays", float(out["replays"]),
         "re-dispatches beyond first (the faults' price)"),
        ("chaos_leaked_blocks", float(out["leaked_blocks"]),
         "KV pool blocks stranded after drain (gate: 0)"),
    ]


def run_smoke(n_requests: int = 16, n_pilots: int = 3) -> list[tuple[str, float, str]]:
    """CI smoke: one kill + one stall + one hedged slow straggler.
    Completion, bitwise and leak gates, and the hedge must actually fire
    (the slow fault runs 40x for several seconds against a 0.3 s straggler
    budget floor, so a held request always crosses it)."""
    cfg = get_smoke_config(ARCH)
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN, seed=0)
    base = _baseline(cfg, trace, n_pilots * SLOTS_PER_PILOT)
    plan = FaultPlan(faults=[
        FaultSpec(kind="slow", at_s=0.05, duration_s=5.0, factor=40.0),
        FaultSpec(kind="crash", at_s=0.15),
        FaultSpec(kind="stall", at_s=0.25, duration_s=2.0),
    ])
    out = serve_fleet(ARCH, n_requests, n_pilots, slots=SLOTS_PER_PILOT,
                      max_len=MAX_LEN, lease_ttl=LEASE_TTL,
                      registry=ExecutableRegistry(), robustness=_policy(),
                      chaos_plan=plan)
    _check_tokens("smoke", out, base["tokens"], n_requests)
    if out["hedges"] < 1:
        raise RuntimeError(
            "the 8x-slow straggler never triggered a hedged re-dispatch")
    return [
        ("chaos_smoke_completed", float(out["completed"]),
         f"of {n_requests}, crash+stall+slow against {n_pilots} pilots"),
        ("chaos_smoke_token_match", 1.0,
         "chaos-run tokens bitwise == no-chaos baseline"),
        ("chaos_smoke_hedges", float(out["hedges"]),
         "straggler rescued by hedged re-dispatch (gate: >= 1)"),
        ("chaos_smoke_leaked_blocks", float(out["leaked_blocks"]),
         "KV pool blocks stranded after drain (gate: 0)"),
    ]
