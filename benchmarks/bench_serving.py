"""Serving-engine throughput on a smoke model: tok/s, TTFT, slot
utilization — the payload-side numbers behind the serve examples."""

from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServeEngine


def run(arch: str = "smollm-360m", n_requests: int = 8,
        slots: int = 4) -> list[tuple[str, float, str]]:
    cfg = get_smoke_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=slots, max_len=96)
    rng = np.random.default_rng(0)
    for i in range(n_requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               size=int(rng.integers(4, 20))),
                           max_new_tokens=12))
    stats = eng.run()
    return [
        ("serve_tok_per_s", stats["tok_per_s"], f"{arch}, {slots} slots"),
        ("serve_mean_ttft_s", stats["mean_ttft_s"], "incl. jit warmup"),
        ("serve_slot_utilization", stats["slot_utilization"],
         "wave batching"),
        ("serve_completed", float(stats["completed"]), f"of {n_requests}"),
    ]
