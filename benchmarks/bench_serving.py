"""Serving-engine benchmark: continuous batching vs the seed wave engine on
a staggered-arrival workload with mixed token budgets.

Two baselines bracket the win:

* ``wave`` — a faithful replica of the seed engine: wave-scheduled
  admission (refill only when every slot drained), decode state reallocated
  per wave, done-checks via per-slot ``int(pos)`` host syncs and an argmax
  round-trip per step.  This is what the continuous engine replaced.
* ``barrier`` — the new device-resident step loop with only the admission
  policy degraded to wave scheduling (``admission="wave"``), isolating how
  much of the win is slot-granular admission vs the loop itself.

Reports tok/s, slot utilization, p50/p99 TTFT and per-output-token latency
(TPOT), and the device→host-transfers-per-step ratio (must be 1.0 — the
decode loop is device-resident).  All engines run the SAME trace with the
same params; each is jit-warmed on a side trace first so the numbers
measure steady-state serving, not compile time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.launch.serve import make_trace
from repro.models.api import build_model, init_decode_state
from repro.serving.engine import Request, ServeEngine, _install_slot

MAX_LEN = 96


class _SeedWaveEngine:
    """The seed's wave-scheduled engine, kept here as the benchmark
    baseline: all slots are refilled together once the LAST request of the
    wave drains; `pos` is one scalar shared by the wave; every step pays an
    argmax host round-trip plus an ``int(pos)`` sync per live slot."""

    def __init__(self, cfg, params, *, slots: int, max_len: int):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.bundle = build_model(cfg)
        self.state = init_decode_state(cfg, slots, max_len)
        self.meta = [[-1, 0] for _ in range(slots)]      # [rid, remaining]
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}
        self._live: dict[int, Request] = {}
        self.steps = 0
        self._decode = jax.jit(self.bundle.decode, donate_argnums=1)
        self._prefill = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def _bucket(self, n):
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_len - 1)

    def _start_wave(self):
        wave, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        if not wave:
            return
        plen = max(self._bucket(len(r.prompt)) for r in wave)
        self.state = init_decode_state(self.cfg, self.slots, self.max_len)
        for si, req in enumerate(wave):
            toks = np.zeros((1, plen), np.int32)
            toks[0, -len(req.prompt):] = req.prompt
            fn = self._prefill.setdefault(plen, jax.jit(
                lambda p, b: self.bundle.prefill(p, b)))
            logits, cache = fn(self.params, {"tokens": jnp.asarray(toks)})
            nxt = int(jnp.argmax(logits[0, -1]))         # per-request sync
            self.state = _install_slot(self.state, cache, si, plen, nxt)
            self.meta[si] = [req.rid, req.max_new_tokens]
            req.tokens.append(nxt)
            req.first_token_s = time.monotonic() - req.submitted
            self._live[req.rid] = req
        self.state = {**self.state, "pos": jnp.asarray(plen, jnp.int32)}

    def step(self) -> int:
        live = [m for m in self.meta if m[0] != -1]
        if not live:
            self._start_wave()
            live = [m for m in self.meta if m[0] != -1]
            if not live:
                return 0
        logits, self.state = self._decode(self.params, self.state)
        self.steps += 1
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for si, m in enumerate(self.meta):
            if m[0] == -1:
                continue
            req = self._live[m[0]]
            req.tokens.append(int(toks[si]))
            m[1] -= 1
            if m[1] <= 0 or int(self.state["pos"]) >= self.max_len - 1:
                req.done_s = time.monotonic() - req.submitted
                self.done[req.rid] = req
                del self._live[m[0]]
                m[0] = -1
        return len(live)


def _drive(eng, trace) -> dict:
    """Tick-driven trace loop (staggered arrivals), shared by both engines."""
    pending = sorted(trace, key=lambda e: e["at_step"])
    t0 = time.monotonic()
    decoded, tick, i = 0, 0, 0
    while i < len(pending) or eng.queue or eng._live:
        while i < len(pending) and pending[i]["at_step"] <= tick:
            e = pending[i]
            i += 1
            eng.submit(Request(rid=e["rid"],
                               prompt=np.asarray(e["prompt"], np.int32),
                               max_new_tokens=e["max_new_tokens"]))
        decoded += eng.step()
        tick += 1
    wall = time.monotonic() - t0
    util = decoded / (eng.steps * eng.slots) if eng.steps else 0.0
    return {"tok_per_s": decoded / wall if wall else 0.0,
            "slot_utilization": util, "completed": len(eng.done)}


def run(arch: str = "smollm-360m", n_requests: int = 32,
        slots: int = 4) -> list[tuple[str, float, str]]:
    cfg = get_smoke_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN,
                       stagger=1, seed=0)
    # warm both prefill buckets (16 and 32) IN SEPARATE WAVES so the seed
    # baseline also compiles each plen before the timed run — its wave
    # admission pads a joint wave to the larger bucket, which would leave
    # the small bucket's compile inside the measured region
    warm = [{"rid": 1000 + i, "prompt": list(range(2, 2 + n)),
             "max_new_tokens": 2, "at_step": i * 8}
            for i, n in enumerate((6, 20))]

    # continuous engine (jit-warm, then measure clean)
    eng = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN)
    eng.run_trace(warm)
    eng.reset_metrics()
    cont = eng.run_trace(trace)

    # degraded-admission variant of the new loop (isolates admission policy)
    engb = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       admission="wave")
    engb.run_trace(warm)
    engb.reset_metrics()
    barrier = engb.run_trace(trace)

    # the seed wave engine (what this PR replaced)
    wv = _SeedWaveEngine(cfg, params, slots=slots, max_len=MAX_LEN)
    _drive(wv, warm)
    wv.steps = 0
    wv.done.clear()
    wave = _drive(wv, trace)

    detail = f"{arch}, {slots} slots, {n_requests} staggered reqs"
    d2h_per_step = (cont["d2h_transfers"] / cont["decode_steps"]
                    if cont["decode_steps"] else 0.0)
    return [
        ("serve_tok_per_s", cont["tok_per_s"], detail),
        ("serve_slot_utilization", cont["slot_utilization"],
         "continuous batching"),
        ("serve_ttft_p50_s", cont["ttft_p50_s"], detail),
        ("serve_ttft_p99_s", cont["ttft_p99_s"], detail),
        ("serve_tpot_p50_s", cont["tpot_p50_s"], "per-output-token latency"),
        ("serve_tpot_p99_s", cont["tpot_p99_s"], "per-output-token latency"),
        ("serve_d2h_per_step", d2h_per_step,
         "device->host transfers per decode step (must be 1)"),
        ("serve_completed", float(cont["completed"]), f"of {n_requests}"),
        ("serve_wave_tok_per_s", wave["tok_per_s"], "seed wave engine"),
        ("serve_wave_slot_utilization", wave["slot_utilization"],
         "seed wave engine"),
        ("serve_speedup_vs_wave", cont["tok_per_s"] / wave["tok_per_s"]
         if wave["tok_per_s"] else float("inf"),
         "continuous / seed wave tok/s"),
        ("serve_barrier_tok_per_s", barrier["tok_per_s"],
         "new loop, wave admission (policy ablation)"),
    ]
