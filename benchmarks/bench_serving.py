"""Serving-engine benchmark: paged KV vs the dense slab, chunked vs
stop-the-world prefill, and the continuous-batching loop vs the seed wave
engine — all on staggered-arrival workloads with mixed token budgets.

Engines under test:

* ``paged``   — the default serve path: block-pool KV + block tables,
  lazy allocation, prefix reuse.  Its pool is sized to the TRACE's worst
  case, not to slots x max_len, so the capacity rows measure how many
  concurrent admitted tokens each HBM byte actually carries.
* ``dense``   — the (slots, max_len) slab ablation (``kv="dense"``).
  Paged decode must produce bitwise-identical token streams; the bench
  RAISES on mismatch (CI runs it as a smoke).
* ``chunked`` — paged + chunked admission prefill on a long-prompt trace,
  against the same engine with stop-the-world (one-shot) admission: the
  p99 per-output-token latency shows decode stalls disappearing.
* ``wave``    — a faithful replica of the seed engine (wave-scheduled
  admission, per-wave state reallocation, per-slot host syncs) and
  ``barrier`` (new loop, wave admission) bracket the PR-2 win.

All engines run the SAME trace with the same params; each is jit-warmed
on a side trace first so the numbers measure steady-state serving, not
compile time.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.launch.serve import make_trace
from repro.models.api import build_model, init_decode_state
from repro.serving.engine import (
    Request, ServeEngine, _install_slot, admit_length)

MAX_LEN = 96
BLOCK = 16


class _SeedWaveEngine:
    """The seed's wave-scheduled engine, kept here as the benchmark
    baseline: all slots are refilled together once the LAST request of the
    wave drains; `pos` is one scalar shared by the wave; every step pays an
    argmax host round-trip plus an ``int(pos)`` sync per live slot."""

    def __init__(self, cfg, params, *, slots: int, max_len: int):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.bundle = build_model(cfg)
        self.state = init_decode_state(cfg, slots, max_len)
        self.meta = [[-1, 0] for _ in range(slots)]      # [rid, remaining]
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}
        self._live: dict[int, Request] = {}
        self.steps = 0
        self._decode = jax.jit(self.bundle.decode, donate_argnums=1)
        self._prefill = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def _bucket(self, n):
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_len - 1)

    def _start_wave(self):
        wave, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        if not wave:
            return
        plen = max(self._bucket(len(r.prompt)) for r in wave)
        self.state = init_decode_state(self.cfg, self.slots, self.max_len)
        for si, req in enumerate(wave):
            toks = np.zeros((1, plen), np.int32)
            toks[0, -len(req.prompt):] = req.prompt
            fn = self._prefill.setdefault(plen, jax.jit(
                lambda p, b: self.bundle.prefill(p, b)))
            logits, cache = fn(self.params, {"tokens": jnp.asarray(toks)})
            nxt = int(jnp.argmax(logits[0, -1]))         # per-request sync
            self.state = _install_slot(self.state, cache, si, plen, nxt)
            self.meta[si] = [req.rid, req.max_new_tokens]
            req.tokens.append(nxt)
            req.first_token_s = time.monotonic() - req.submitted
            self._live[req.rid] = req
        self.state = {**self.state, "pos": jnp.asarray(plen, jnp.int32)}

    def step(self) -> int:
        live = [m for m in self.meta if m[0] != -1]
        if not live:
            self._start_wave()
            live = [m for m in self.meta if m[0] != -1]
            if not live:
                return 0
        logits, self.state = self._decode(self.params, self.state)
        self.steps += 1
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for si, m in enumerate(self.meta):
            if m[0] == -1:
                continue
            req = self._live[m[0]]
            req.tokens.append(int(toks[si]))
            m[1] -= 1
            if m[1] <= 0 or int(self.state["pos"]) >= self.max_len - 1:
                req.done_s = time.monotonic() - req.submitted
                self.done[req.rid] = req
                del self._live[m[0]]
                m[0] = -1
        return len(live)


def _drive(eng, trace) -> dict:
    """Tick-driven trace loop (staggered arrivals), shared with the seed
    wave engine (which predates run_trace)."""
    pending = sorted(trace, key=lambda e: e["at_step"])
    t0 = time.monotonic()
    decoded, tick, i = 0, 0, 0
    while i < len(pending) or eng.queue or eng._live:
        while i < len(pending) and pending[i]["at_step"] <= tick:
            e = pending[i]
            i += 1
            eng.submit(Request(rid=e["rid"],
                               prompt=np.asarray(e["prompt"], np.int32),
                               max_new_tokens=e["max_new_tokens"]))
        decoded += eng.step()
        tick += 1
    wall = time.monotonic() - t0
    util = decoded / (eng.steps * eng.slots) if eng.steps else 0.0
    return {"tok_per_s": decoded / wall if wall else 0.0,
            "slot_utilization": util, "completed": len(eng.done)}


def _trace_pool_blocks(trace, slots: int, max_len: int, bs: int) -> int:
    """Smallest pool that can hold `slots` concurrent worst-case requests
    of this trace (what a demand-shaped deployment would provision)."""
    worst = max(-(-min(admit_length(len(e["prompt"]), max_len)
                       + e["max_new_tokens"], max_len) // bs)
                for e in trace)
    return slots * worst + 1                     # + scratch block


def _tokens_by_rid(eng) -> dict:
    return {rid: tuple(r.tokens) for rid, r in eng.done.items()}


def _assert_token_match(a, b, label):
    ta, tb = _tokens_by_rid(a), _tokens_by_rid(b)
    if ta != tb:
        bad = [r for r in ta if ta.get(r) != tb.get(r)]
        raise RuntimeError(
            f"dense-vs-paged output mismatch ({label}): rids {bad[:4]}")


def _prefix_trace(vocab: int, n: int, max_len: int, seed: int = 5):
    """Half the requests repeat one LONG prompt (a shared system prompt /
    repeated query): its full blocks below the tail are mapped copy-free.
    Short (bucket-16) prompts can never share — their single block holds
    the last prompt position, which admission must recompute."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=min(40, max_len - 8)).tolist()
    trace = []
    for i in range(n):
        if i % 2:
            prompt = list(base)
        else:
            prompt = rng.integers(0, vocab,
                                  size=int(rng.integers(4, 20))).tolist()
        trace.append({
            "rid": i,
            "prompt": prompt,
            "max_new_tokens": int(rng.choice([4, 8, 12])),
            "at_step": i,
        })
    return trace


def _long_mix_trace(vocab: int, n: int, max_len: int, seed: int = 7):
    """Short decodes punctuated by LONG prompts: the workload where a
    stop-the-world admission stalls every running slot."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n):
        if i % 4 == 2:
            plen = int(rng.integers(60, max_len - 2))    # bucket 64 / 95
        else:
            plen = int(rng.integers(4, 20))
        trace.append({
            "rid": i,
            "prompt": rng.integers(0, vocab, size=plen).tolist(),
            "max_new_tokens": int(rng.choice([8, 12, 20])),
            "at_step": i * 2,
        })
    return trace


def _bench_config(arch: str):
    """The smoke configs are deliberately tiny (d_model 60) — at that size
    cache plumbing, not matmuls, dominates a decode step and every engine
    comparison measures dispatch overhead.  Scale the model so the decode
    math is the cost, as it is in any real deployment."""
    cfg = get_smoke_config(arch)
    return dataclasses.replace(
        cfg, name=cfg.name + "-bench", d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=2048)


def run(arch: str = "smollm-360m", n_requests: int = 32,
        slots: int = 4) -> list[tuple[str, float, str]]:
    cfg = _bench_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN,
                       stagger=1, seed=0)
    dup_trace = _prefix_trace(cfg.vocab_size, n_requests, MAX_LEN)
    # warm both prefill buckets (16 and 32) IN SEPARATE WAVES so the seed
    # baseline also compiles each plen before the timed run — its wave
    # admission pads a joint wave to the larger bucket, which would leave
    # the small bucket's compile inside the measured region
    warm = [{"rid": 1000 + i, "prompt": list(range(2, 2 + n)),
             "max_new_tokens": 2, "at_step": i * 8}
            for i, n in enumerate((6, 20))]

    # paged engine: pool sized to the trace (the demand-shaped claim), not
    # to slots x max_len
    pool_blocks = _trace_pool_blocks(trace, slots, MAX_LEN, BLOCK)
    engp = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       kv="paged", num_blocks=pool_blocks)
    engp.run_trace(warm)
    engp.reset_metrics()
    paged = engp.run_trace(trace)

    # dense slab ablation (identical trace; token streams must match)
    engd = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       kv="dense")
    engd.run_trace(warm)
    engd.reset_metrics()
    dense = engd.run_trace(trace)
    _assert_token_match(engd, engp, "staggered trace")

    # prefix reuse: repeated-prompt trace on the paged engine
    prefix = None
    if engp.prefix is not None:
        engp.reset_metrics()
        prefix = engp.run_trace(dup_trace)

    # chunked vs stop-the-world admission on the long-prompt mix
    long_trace = _long_mix_trace(cfg.vocab_size, max(8, n_requests // 2),
                                 MAX_LEN)
    long_warm = [{"rid": 2000 + i, "prompt": list(range(2, 2 + n)),
                  "max_new_tokens": 2, "at_step": i * 10}
                 for i, n in enumerate((6, 20, 60, MAX_LEN - 2))]
    engc = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       kv="paged", prefill="chunked", prefill_chunk=32)
    engc.warm_admission()                 # stage EVERY chunk shape
    engc.run_trace(long_warm)
    engc.reset_metrics()
    chunked = engc.run_trace(long_trace)
    engo = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       kv="paged", prefill="oneshot")
    engo.run_trace(long_warm)
    engo.reset_metrics()
    oneshot = engo.run_trace(long_trace)

    # degraded-admission variant of the new loop (isolates admission policy)
    engb = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       kv="dense", admission="wave")
    engb.run_trace(warm)
    engb.reset_metrics()
    barrier = engb.run_trace(trace)

    # the seed wave engine (what PR 2 replaced)
    wv = _SeedWaveEngine(cfg, params, slots=slots, max_len=MAX_LEN)
    _drive(wv, warm)
    wv.steps = 0
    wv.done.clear()
    wave = _drive(wv, trace)

    detail = f"{arch}, {slots} slots, {n_requests} staggered reqs"
    d2h_per_step = (paged["d2h_transfers"] / paged["decode_steps"]
                    if paged["decode_steps"] else 0.0)
    # effective cache capacity: concurrent admitted tokens per token of
    # allocated HBM (higher = each byte of claim carries more traffic)
    eff_p = paged["kv_peak_live_tokens"] / paged["kv_capacity_tokens"]
    eff_d = dense["kv_peak_live_tokens"] / dense["kv_capacity_tokens"]
    rows = [
        ("serve_tok_per_s", paged["tok_per_s"], detail + " (paged)"),
        ("serve_slot_utilization", paged["slot_utilization"],
         "continuous batching, paged KV"),
        ("serve_ttft_p50_s", paged["ttft_p50_s"], detail),
        ("serve_ttft_p99_s", paged["ttft_p99_s"], detail),
        ("serve_tpot_p50_s", paged["tpot_p50_s"], "per-output-token latency"),
        ("serve_tpot_p99_s", paged["tpot_p99_s"], "per-output-token latency"),
        ("serve_d2h_per_step", d2h_per_step,
         "device->host transfers per decode step (must be 1)"),
        ("serve_completed", float(paged["completed"]), f"of {n_requests}"),
        ("serve_paged_token_match", 1.0,
         "paged token streams bitwise == dense (raises otherwise)"),
        ("serve_dense_tok_per_s", dense["tok_per_s"], "dense slab ablation"),
        ("serve_paged_vs_dense_tok_ratio",
         paged["tok_per_s"] / dense["tok_per_s"] if dense["tok_per_s"]
         else float("inf"), "must stay ~1 (capacity is the win, not speed)"),
        ("serve_paged_capacity_tokens", float(paged["kv_capacity_tokens"]),
         f"pool {pool_blocks} blocks x {BLOCK}"),
        ("serve_dense_capacity_tokens", float(dense["kv_capacity_tokens"]),
         f"slab {slots} x {MAX_LEN}"),
        ("serve_paged_eff_capacity", eff_p,
         "peak concurrent admitted tokens / cache capacity tokens"),
        ("serve_dense_eff_capacity", eff_d,
         "peak concurrent admitted tokens / cache capacity tokens"),
        ("serve_paged_capacity_gain", eff_p / eff_d if eff_d else float("inf"),
         "paged / dense effective capacity (target >= 1.3 at equal tok/s)"),
        ("serve_kv_mem_util_paged", paged["kv_memory_utilization"],
         "live tokens / ALLOCATED tokens, mean over steps"),
        ("serve_kv_mem_util_dense", dense["kv_memory_utilization"],
         "live tokens / allocated tokens (slab allocates everything)"),
        ("serve_chunked_itl_p99_s", chunked["itl_p99_s"],
         "p99 per-token stall, chunked prefill, long-prompt mix"),
        ("serve_oneshot_itl_p99_s", oneshot["itl_p99_s"],
         "p99 per-token stall, stop-the-world prefill, long-prompt mix"),
        ("serve_chunked_itl_p99_gain",
         oneshot["itl_p99_s"] / chunked["itl_p99_s"]
         if chunked["itl_p99_s"] else float("inf"),
         "oneshot p99 stall / chunked p99 stall (>1 = stalls removed)"),
        ("serve_chunked_tpot_p99_s", chunked["tpot_p99_s"],
         "chunked prefill, long-prompt mix"),
        ("serve_oneshot_tpot_p99_s", oneshot["tpot_p99_s"],
         "stop-the-world prefill, long-prompt mix"),
        ("serve_chunked_prefill_chunks", float(chunked["prefill_chunks"]),
         "admission chunks interleaved with decode"),
        ("serve_wave_tok_per_s", wave["tok_per_s"], "seed wave engine"),
        ("serve_wave_slot_utilization", wave["slot_utilization"],
         "seed wave engine"),
        ("serve_speedup_vs_wave", paged["tok_per_s"] / wave["tok_per_s"]
         if wave["tok_per_s"] else float("inf"),
         "paged continuous / seed wave tok/s"),
        ("serve_barrier_tok_per_s", barrier["tok_per_s"],
         "new loop, wave admission (policy ablation)"),
    ]
    if prefix is not None:
        rows += [
            ("serve_prefix_hit_rate", prefix["prefix_hit_rate"],
             "50% repeated prompts: fraction of prompt tokens mapped "
             "copy-free"),
            ("serve_prefix_kv_mem_util", prefix["kv_memory_utilization"],
             "live / allocated under prefix sharing"),
        ]
    return rows


def run_smoke(arch: str = "smollm-360m") -> list[tuple[str, float, str]]:
    """CI smoke: `bench_serving --kv paged --smoke` — a small staggered
    trace through the paged AND dense engines; RAISES on any dense-vs-paged
    token-stream mismatch."""
    cfg = get_smoke_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    # half the prompts repeat one bucket-64 prompt, so prefix reuse fires
    # (bucket-16 prompts have no shareable full block below their tail)
    trace = _prefix_trace(cfg.vocab_size, 8, 96, seed=2)
    engp = ServeEngine(cfg, params, slots=2, max_len=96, kv="paged")
    paged = engp.run_trace(trace)
    engd = ServeEngine(cfg, params, slots=2, max_len=96, kv="dense")
    dense = engd.run_trace(trace)
    _assert_token_match(engd, engp, "smoke trace")
    engc = ServeEngine(cfg, params, slots=2, max_len=96, kv="paged",
                       prefill="chunked", prefill_chunk=16)
    chunked = engc.run_trace(trace)
    if chunked["completed"] != paged["completed"]:
        raise RuntimeError("chunked prefill dropped requests: "
                           f"{chunked['completed']} != {paged['completed']}")
    return [
        ("serve_smoke_paged_token_match", 1.0,
         "paged bitwise == dense on the smoke trace"),
        ("serve_smoke_completed", float(paged["completed"]), "of 8"),
        ("serve_smoke_prefix_hit_rate", paged["prefix_hit_rate"],
         "50% repeated long prompts"),
        ("serve_smoke_chunked_chunks", float(chunked["prefill_chunks"]),
         "chunked admission ran"),
        ("serve_smoke_kv_mem_util", paged["kv_memory_utilization"],
         "live / allocated tokens"),
    ]
