"""Fleet-serving benchmark: goodput + tail TTFT under injected pilot death.

One request trace is split into per-request leases in a FleetDispatcher
pool; N serving pilots pull from it.  Scenarios:

* ``baseline`` — ONE engine with the fleet's aggregate slot count runs the
  same trace directly (no pool, no pilots): the ceiling a failure-free,
  dispatch-free deployment reaches.
* ``f0/f1/f2`` — the fleet with 0, 1 and 2 pilots hard-killed mid-trace
  (``ClusterSim.fail_node`` on a lease-holding pilot).  A dead pilot's
  in-flight requests requeue via lease expiry and replay on survivors.

Every scenario must complete 100% of the trace with token streams BITWISE
equal to the baseline engine's (greedy decode over slot-isolated state is
deterministic and every server holds identical weights) — the run RAISES on
a drop or a mismatch, and on the acceptance gate: 1-of-N-pilots death must
keep p99 TTFT within 3x of the no-failure fleet run.

TTFT here is pool-level: submit-to-first-token, INCLUDING requeue delay
(the lease TTL a failed request waits out) — the metric the failure story
actually moves.
"""

from __future__ import annotations

import jax

from repro.configs.base import get_smoke_config
from repro.core.images import ExecutableRegistry
from repro.launch.serve import make_trace, serve_fleet
from repro.models.api import build_model
from repro.serving.engine import Request, ServeEngine

ARCH = "smollm-360m"
MAX_LEN = 64
SLOTS_PER_PILOT = 2
LEASE_TTL = 0.4


def _baseline(cfg, trace, slots: int) -> dict:
    """One pre-warmed engine, the whole trace, no pilots in the way."""
    import numpy as np

    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN)
    eng.warm_admission()
    eng.warm_install()
    stats = eng.run_trace([{**e, "at_step": 0} for e in trace])
    stats["tokens"] = {rid: list(np.asarray(r.tokens).tolist())
                      for rid, r in eng.done.items()}
    return stats


def _check(label: str, n_requests: int, out: dict, base_tokens: dict):
    if out["completed"] != n_requests:
        raise RuntimeError(
            f"fleet run {label} completed {out['completed']}/{n_requests} "
            f"requests — requeue-on-failure lost work")
    for rid, toks in out["results"].items():
        if list(toks) != list(base_tokens[rid]):
            raise RuntimeError(
                f"fleet run {label}: rid {rid} token stream diverged from "
                f"the single-engine baseline (replay is not deterministic?)")


def run(n_requests: int = 24, n_pilots: int = 4) -> list[tuple[str, float, str]]:
    cfg = get_smoke_config(ARCH)
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN, seed=0)
    base = _baseline(cfg, trace, n_pilots * SLOTS_PER_PILOT)

    registry = ExecutableRegistry()       # shared: scenarios reuse compiles
    outs = {}
    for f in (0, 1, 2):
        outs[f] = serve_fleet(
            ARCH, n_requests, n_pilots, slots=SLOTS_PER_PILOT,
            max_len=MAX_LEN, fail_at=4 if f else None, fail_count=f,
            lease_ttl=LEASE_TTL, registry=registry)
        _check(f"f{f}", n_requests, outs[f], base["tokens"])
        if len(outs[f]["failed_pilots"]) != f:
            raise RuntimeError(
                f"failure injection f{f} killed "
                f"{len(outs[f]['failed_pilots'])} pilots, wanted {f}")

    ratio1 = (outs[1]["ttft_p99_s"] / outs[0]["ttft_p99_s"]
              if outs[0]["ttft_p99_s"] else float("inf"))
    if ratio1 > 3.0:
        raise RuntimeError(
            f"1-of-{n_pilots} pilot death pushed p99 TTFT to {ratio1:.2f}x "
            f"the no-failure run (acceptance gate: <= 3x)")

    detail = (f"{ARCH}, {n_pilots} pilots x {SLOTS_PER_PILOT} slots, "
              f"{n_requests} reqs, lease_ttl {LEASE_TTL}s")
    rows = [
        ("fleet_baseline_tok_per_s", base["tok_per_s"],
         "single engine, aggregate slots, no pool"),
        ("fleet_baseline_ttft_p99_s", base["ttft_p99_s"], "single engine"),
        ("fleet_token_match", 1.0,
         "every fleet scenario bitwise == baseline tokens (raises otherwise)"),
    ]
    for f in (0, 1, 2):
        o = outs[f]
        rows += [
            (f"fleet_goodput_tok_per_s_f{f}", o["goodput_tok_per_s"],
             f"{detail}, {f} pilot(s) killed"),
            (f"fleet_completed_f{f}", float(o["completed"]),
             f"of {n_requests} (must be all)"),
            (f"fleet_ttft_p99_s_f{f}", o["ttft_p99_s"],
             "pool-level TTFT incl. requeue delay"),
            (f"fleet_replays_f{f}", float(o["replays"]),
             "re-dispatches beyond first (the failures' price)"),
        ]
    rows += [
        ("fleet_ttft_p99_ratio_f1", ratio1,
         "1-pilot-death p99 TTFT / no-failure p99 TTFT (gate: <= 3)"),
        ("fleet_goodput_retained_f1",
         outs[1]["goodput_tok_per_s"] / outs[0]["goodput_tok_per_s"]
         if outs[0]["goodput_tok_per_s"] else float("inf"),
         "goodput after losing 1 of 4 pilots mid-trace"),
        ("fleet_duplicates_f2", float(outs[2]["duplicates"]),
         "completions dropped by first-wins (duplicates never double-count)"),
    ]
    return rows


def run_smoke(n_requests: int = 16, n_pilots: int = 4) -> list[tuple[str, float, str]]:
    """CI smoke: the headline scenario only — kill 1 of 4 serving pilots
    mid-trace, demand 100% completion, bitwise-baseline tokens and the
    <= 3x p99 TTFT gate."""
    cfg = get_smoke_config(ARCH)
    trace = make_trace(cfg.vocab_size, n_requests, max_len=MAX_LEN, seed=0)
    base = _baseline(cfg, trace, n_pilots * SLOTS_PER_PILOT)
    registry = ExecutableRegistry()
    o0 = serve_fleet(ARCH, n_requests, n_pilots, slots=SLOTS_PER_PILOT,
                     max_len=MAX_LEN, lease_ttl=LEASE_TTL, registry=registry)
    _check("f0", n_requests, o0, base["tokens"])
    o1 = serve_fleet(ARCH, n_requests, n_pilots, slots=SLOTS_PER_PILOT,
                     max_len=MAX_LEN, fail_at=3, lease_ttl=LEASE_TTL,
                     registry=registry)
    _check("f1", n_requests, o1, base["tokens"])
    if not o1["failed_pilots"]:
        raise RuntimeError("failure injection did not kill a pilot")
    ratio = (o1["ttft_p99_s"] / o0["ttft_p99_s"]
             if o0["ttft_p99_s"] else float("inf"))
    if ratio > 3.0:
        raise RuntimeError(
            f"p99 TTFT {ratio:.2f}x the no-failure run (gate: <= 3x)")
    return [
        ("fleet_smoke_completed_f1", float(o1["completed"]),
         f"of {n_requests}, 1 of {n_pilots} pilots killed mid-trace"),
        ("fleet_smoke_token_match", 1.0,
         "failure-run tokens bitwise == single-engine baseline"),
        ("fleet_smoke_replays", float(o1["replays"]),
         "dead pilot's in-flight requests replayed on survivors"),
        ("fleet_smoke_ttft_p99_ratio", ratio,
         "p99 TTFT vs no-failure fleet (gate: <= 3)"),
    ]
