"""Late-binding cost (paper Fig. 4): cold bind vs warm rebind vs
prefetched bind vs full re-provision.

The paper's core claim is that swapping the payload image on an
already-held resource is cheap and unprivileged.  We quantify the four
options a scheduler has when the next task needs a different image:

  cold_bind      — pod patch + image pull (XLA compile) on a held slice
  warm_rebind    — pod patch with the image already in the node cache
  prefetched     — pod patch after `ExecutableRegistry.prefetch` overlapped
                   the compile with the previous payload's run (the hint
                   riding on a matched task) — pays only the un-overlapped
                   tail of the pull
  re-provision   — release the slice, acquire a new one, start a pilot,
                   then cold-bind (what option (b) in paper §2 forces)
"""

from __future__ import annotations

import time

from repro.core.arena import SharedArena
from repro.core.cluster import ClusterSim
from repro.core.images import ExecutableRegistry, PayloadImage
from repro.core.latebind import PayloadExecutor, PodPatchCapability
from repro.core.pilot import PilotConfig
from repro.core.proctable import ProcessTable

IMAGES = [PayloadImage("smollm-360m", "smoke", "decode"),
          PayloadImage("gemma-2b", "smoke", "decode"),
          PayloadImage("mamba2-370m", "smoke", "decode")]


def run() -> list[tuple[str, float, str]]:
    out = []
    reg = ExecutableRegistry()
    arena = SharedArena()
    ex = PayloadExecutor("pod-bench", arena, ProcessTable(), reg)
    cap = PodPatchCapability("pod-bench")

    import jax

    def bind_to_first_step(img):
        """patch + one executed step: jax jit is lazy, so the XLA compile
        (the 'image pull') lands on the first invocation."""
        t0 = time.monotonic()
        exe = ex.patch_image(cap, img)
        params, state = exe.make_inputs(jax.random.key(0))
        logits, _ = exe.fn(params, state)
        jax.block_until_ready(logits)
        return time.monotonic() - t0

    colds = [bind_to_first_step(img) for img in IMAGES]
    warms = [bind_to_first_step(img) for img in IMAGES]
    arena.destroy()

    # prefetched bind: a fresh registry, compile started in the background
    # (the pilot's prefetch hint) while the "current payload" runs; by bind
    # time the pull is a cache hit — only the executed step remains.
    arena2 = SharedArena()
    reg2 = ExecutableRegistry()
    ex2 = PayloadExecutor("pod-bench2", arena2, ProcessTable(), reg2)
    cap2 = PodPatchCapability("pod-bench2")
    ev = reg2.prefetch(IMAGES[0])
    ev.wait(timeout=300.0)               # the previous payload's run window
    t0 = time.monotonic()
    exe = ex2.patch_image(cap2, IMAGES[0])
    params, state = exe.make_inputs(jax.random.key(0))
    logits, _ = exe.fn(params, state)
    jax.block_until_ready(logits)
    prefetched = time.monotonic() - t0
    prefetched_cached = bool(ex2.last_bind_cached)
    arena2.destroy()

    # full re-provision path: new pilot on a new slice running one payload
    sim = ClusterSim(registry=ExecutableRegistry())      # cold registry
    tid = sim.repo.submit(IMAGES[0], n_steps=1)
    t0 = time.monotonic()
    (s,) = sim.provision(1)
    sim.spawn_pilot(s, PilotConfig(max_payloads=1, idle_grace=0.5))
    sim.run_until_drained(timeout=300.0)
    reprov = time.monotonic() - t0
    sim.join_all(10.0)

    # serve-image admission staging: a prefetched serve image has a jitted
    # prefill trace for EVERY admit-length bucket, so the first request of
    # each bucket skips the retrace spike a cold bind pays mid-serve
    import numpy as np

    from repro.serving.engine import Request, admit_buckets

    serve_img = PayloadImage("smollm-360m", "smoke", "serve")

    def bucket_first_request_times(reg) -> list[float]:
        exe = reg.pull(serve_img)
        params = exe.make_inputs(jax.random.key(0))
        eng = exe.fn(params)
        times = []
        for i, b in enumerate(admit_buckets(eng.max_len)):
            eng.submit(Request(rid=i, prompt=np.arange(2, 2 + b - 1,
                                                       dtype=np.int32),
                               max_new_tokens=2))
            t0 = time.monotonic()
            eng.step()                     # admission = this bucket's prefill
            times.append(time.monotonic() - t0)
            eng.run()                      # drain before the next bucket
        return times

    cold_buckets = bucket_first_request_times(ExecutableRegistry())
    reg4 = ExecutableRegistry()
    reg4.prefetch(serve_img).wait(timeout=600.0)
    warm_buckets = bucket_first_request_times(reg4)

    # role-restricted warm: a disaggregated image only stages the step fns
    # its role can run (prefill: admission traces only; decode: the decode
    # step only), so its prefetch finishes sooner than the unified image's.
    # Unified is measured LAST so the process-global eager-op cache biases
    # AGAINST the role images — the reported speedups are conservative.
    import dataclasses

    def role_warm_time(role: str) -> float:
        reg = ExecutableRegistry()
        img = dataclasses.replace(serve_img, role=role)
        t0 = time.monotonic()
        reg.prefetch(img).wait(timeout=600.0)
        return time.monotonic() - t0

    warm_prefill = role_warm_time("prefill")
    warm_decode = role_warm_time("decode")
    warm_unified = role_warm_time("unified")

    # sharded (mesh-bound) serve image: the registry keys compiles per
    # (image, mesh), so a prefetch staged for the pilot's held devices is
    # a cache hit at bind time even though the unsharded image compiled
    # separately.  On a 1-device host the mesh is (1,1) — same code path,
    # degenerate shard count.
    tp_img = PayloadImage("smollm-360m", "smoke", "serve",
                          mesh_shape=(1, jax.device_count()))
    tp_mesh = tp_img.build_mesh()

    def tp_first_step(reg) -> float:
        t0 = time.monotonic()
        exe = reg.pull(tp_img, tp_mesh)
        params = exe.make_inputs(jax.random.key(0))
        eng = exe.fn(params)
        eng.submit(Request(rid=0, prompt=np.arange(2, 9, dtype=np.int32),
                           max_new_tokens=2))
        eng.step()
        return time.monotonic() - t0

    tp_cold = tp_first_step(ExecutableRegistry())
    reg5 = ExecutableRegistry()
    reg5.prefetch(tp_img, tp_mesh).wait(timeout=600.0)
    tp_warm = tp_first_step(reg5)

    cold = sum(colds) / len(colds)
    warm = sum(warms) / len(warms)
    out.append(("serve_bucket_cold_s", max(cold_buckets),
                "worst first-request-of-a-bucket admission, cold bind"))
    out.append(("serve_bucket_prewarmed_s", max(warm_buckets),
                "same, after prefetch staged every bucket's prefill"))
    out.append(("serve_bucket_prewarm_speedup",
                max(cold_buckets) / max(warm_buckets),
                "x vs cold (first-request retrace spike removed)"))
    out.append(("serve_warm_unified_s", warm_unified,
                "prefetch+warm, every role's step fns staged"))
    out.append(("serve_warm_prefill_s", warm_prefill,
                "prefill-role image: admission traces only"))
    out.append(("serve_warm_decode_s", warm_decode,
                "decode-role image: the decode step only"))
    out.append(("serve_role_warm_speedup",
                warm_unified / max(warm_prefill, warm_decode),
                "x vs unified (slower of the two role images)"))
    out.append(("serve_tp_bind_cold_s", tp_cold,
                f"mesh-keyed serve image {tp_img.mesh_shape}, cold bind"))
    out.append(("serve_tp_bind_prefetched_s", tp_warm,
                "same, after a per-(image, mesh) prefetch"))
    out.append(("serve_tp_bind_speedup", tp_cold / tp_warm, "x vs cold"))
    out.append(("bind_cold_s", cold, "image pull = XLA compile"))
    out.append(("bind_warm_s", warm, "cache hit (image already pulled)"))
    out.append(("bind_warm_speedup", cold / warm, "x vs cold"))
    out.append(("bind_prefetched_s", prefetched,
                f"pull overlapped with prior payload (cached={prefetched_cached})"))
    out.append(("bind_prefetch_speedup", cold / prefetched, "x vs cold"))
    out.append(("reprovision_s", reprov,
                "release+acquire+pilot-start+cold-bind+run"))
    out.append(("latebind_vs_reprovision", reprov / warm, "x"))
    return out
