"""Tensor-parallel sharded serving benchmark (paper §late-binding over
held multi-device slices).

A mesh-bound serve payload late-binds one SPMD engine over the devices
its pilot already holds: paged KV pools shard on the head (GQA) /
latent (MLA) dim over the "model" axis, Pallas paged-attention runs
under ``shard_map``, and the packed per-step device->host transfer
stays exactly ONE fully-replicated array — so continuous batching,
prefix COW and speculative decode work unchanged on top.

The serve-TP rules are ORDER-PRESERVING (column-parallel params only;
every cross-shard contraction gathers first): the sharded engine's
token streams are bitwise identical to the single-device engine's, and
the bench RAISES on any divergence, on a broken one-transfer invariant,
and on a per-device KV-pool footprint above 0.6x the single-device
pool on a 2-way mesh.

Needs >1 device, and XLA's forced host-device count must be set before
jax imports — so the measured section self-spawns as a child process
(``--child``) with ``--xla_force_host_platform_device_count=2``; the
parent stays device-count agnostic and just gates the child's JSON.

  smoke: GQA (Pallas paged attention) only, short trace — the CI gate.
  full:  GQA + MLA + GQA-with-speculation, longer trace; records tok/s
         sharded vs single and per-device KV bytes for each.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# child: the only process that sees >1 device
# ---------------------------------------------------------------------------

def _child(mode: str) -> None:
    import dataclasses
    import time

    import jax

    import repro.configs.base as b
    from repro.launch.serve import make_trace
    from repro.models.api import build_model
    from repro.runtime.mesh import serve_mesh
    from repro.serving.engine import ServeEngine

    n_req = 6 if mode == "smoke" else 16
    max_len = 64 if mode == "smoke" else 96
    cases = [("gqa", "starcoder2-3b", {"attn_impl": "pallas"}, {})]
    if mode == "full":
        cases += [("mla", "minicpm3-4b", {}, {}),
                  ("gqa_spec", "starcoder2-3b", {"attn_impl": "pallas"},
                   {"spec": "draft", "spec_k": 3})]

    def run(cfg, mesh, **kw):
        params = build_model(cfg).init(jax.random.key(0))
        eng = ServeEngine(cfg, params, slots=2, max_len=max_len,
                          mesh=mesh, **kw)
        trace = make_trace(cfg.vocab_size, n_req, max_len=max_len,
                           seed=0, dup_rate=0.3)
        t0 = time.monotonic()
        eng.run_trace(trace)
        wall = time.monotonic() - t0
        toks = {r.rid: list(r.tokens) for r in eng.done.values()}
        return eng, toks, sum(len(t) for t in toks.values()) / wall

    out = {"devices": jax.device_count()}
    mesh = serve_mesh((1, 2))
    for name, arch, flags, kw in cases:
        cfg = b.get_smoke_config(arch)
        if flags:
            cfg = dataclasses.replace(cfg, **flags)
        e1, t1, tps1 = run(cfg, None, **kw)
        e2, t2, tps2 = run(cfg, mesh, **kw)
        kvb = e2.kv_pool_bytes()
        out[name] = {
            "parity": t1 == t2,
            "d2h_per_step": e2.d2h_transfers / max(1, e2.steps),
            "kv_bytes_single": e1.kv_pool_bytes()["kv_pool_bytes_per_device"],
            "kv_bytes_per_device": kvb["kv_pool_bytes_per_device"],
            "kv_ratio": (kvb["kv_pool_bytes_per_device"]
                         / kvb["kv_pool_bytes"]),
            "tok_s_single": tps1,
            "tok_s_sharded": tps2,
        }
    json.dump(out, sys.stdout)


# ---------------------------------------------------------------------------
# parent: spawn, gate, report
# ---------------------------------------------------------------------------

def _spawn(mode: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + str(REPO)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_tp_serve", "--child", mode],
        capture_output=True, text=True, timeout=3600, env=env,
        cwd=str(REPO))
    if r.returncode != 0:
        raise RuntimeError(f"tp_serve child failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout)


def _gate(rec: dict, name: str) -> None:
    if not rec["parity"]:
        raise AssertionError(f"{name}: sharded tokens != single-device")
    if rec["d2h_per_step"] != 1.0:
        raise AssertionError(
            f"{name}: one-transfer invariant broken ({rec['d2h_per_step']})")
    if rec["kv_ratio"] > 0.6:
        raise AssertionError(
            f"{name}: per-device KV pool {rec['kv_ratio']:.2f}x > 0.6x")


def _rows(out: dict, cases) -> list:
    rows = []
    for name in cases:
        rec = out[name]
        _gate(rec, name)
        rows += [
            (f"tp_{name}_bitwise_parity", 1.0,
             "sharded == single-device token streams"),
            (f"tp_{name}_d2h_per_step", rec["d2h_per_step"],
             "packed transfers per decode step (must be 1)"),
            (f"tp_{name}_kv_bytes_per_device", rec["kv_bytes_per_device"],
             f"vs {rec['kv_bytes_single']} single-device"),
            (f"tp_{name}_kv_ratio", rec["kv_ratio"],
             "per-device / total pool bytes on 1x2 mesh"),
            (f"tp_{name}_tok_s_sharded", rec["tok_s_sharded"],
             f"single-device {rec['tok_s_single']:.1f} tok/s"),
        ]
    return rows


def run_smoke():
    """CI gate: bitwise parity + one-transfer + sharded pools on a 1x2
    host mesh, GQA via the Pallas paged-attention kernel under
    shard_map."""
    return _rows(_spawn("smoke"), ["gqa"])


def run():
    """Full battery: GQA, MLA and GQA+speculative-decode, longer trace."""
    return _rows(_spawn("full"), ["gqa", "mla", "gqa_spec"])


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--child":
        _child(sys.argv[2])
    else:
        for row in (run_smoke() if "--smoke" in sys.argv else run()):
            print(row)
