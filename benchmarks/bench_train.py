"""Train-step wall time for smoke configs (CPU numbers; the TPU-target
numbers are the §Roofline table from the dry-run)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.steps import init_train_state, make_train_step
from repro.optim.adamw import OptimConfig


def run(archs=("smollm-360m", "mamba2-370m", "mixtral-8x7b"),
        steps: int = 5) -> list[tuple[str, float, str]]:
    out = []
    for arch in archs:
        cfg = get_smoke_config(arch)
        step = jax.jit(make_train_step(cfg, OptimConfig()), donate_argnums=0)
        state = init_train_state(cfg, jax.random.key(0))
        data = SyntheticLM(SyntheticConfig(cfg.vocab_size, 128, 4))
        b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        state, m = step(state, b)                       # compile + warm
        jax.block_until_ready(m["loss"])
        t0 = time.monotonic()
        for i in range(1, steps + 1):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, m = step(state, b)
        jax.block_until_ready(m["loss"])
        dt = (time.monotonic() - t0) / steps
        toks = 4 * 128
        out.append((f"train_ms_per_step_{arch}", dt * 1e3,
                    f"smoke cfg, {toks} tok/step, loss={float(m['loss']):.3f}"))
        out.append((f"train_tok_per_s_{arch}", toks / dt, "CPU"))
    return out
