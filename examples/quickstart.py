"""Quickstart: the whole system in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. builds a reduced LM and takes a few training steps directly;
2. stands up the pilot system (cluster sim + task repo), submits train and
   serve payloads for TWO different models, and lets ONE pilot run them all
   on a single resource claim — container late-binding end to end.
"""

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.steps import init_train_state, make_train_step
from repro.optim.adamw import OptimConfig

# ---- 1. direct training ----------------------------------------------------

cfg = get_smoke_config("smollm-360m")
step = jax.jit(make_train_step(cfg, OptimConfig(total_steps=50)),
               donate_argnums=0)
state = init_train_state(cfg, jax.random.key(0))
data = SyntheticLM(SyntheticConfig(cfg.vocab_size, seq_len=128, global_batch=4,
                                   structure=0.9))
print("== direct training ==")
for i in range(10):
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
    state, metrics = step(state, batch)
    if i % 3 == 0:
        print(f"  step {i}: loss {float(metrics['loss']):.4f}")

# ---- 2. the pilot system ----------------------------------------------------

print("== pilot system: one slice, three payloads, two models ==")
sim = ClusterSim()
tasks = [
    sim.repo.submit(PayloadImage("smollm-360m", "smoke", "train"), n_steps=3),
    sim.repo.submit(PayloadImage("smollm-360m", "smoke", "decode"), n_steps=4),
    sim.repo.submit(PayloadImage("gemma-2b", "smoke", "decode"), n_steps=4),
]
(slice_,) = sim.provision(1)
pilot = sim.spawn_pilot(slice_, PilotConfig(max_payloads=4, idle_grace=1.0))
assert sim.run_until_drained(timeout=300.0), "queue did not drain"
sim.join_all(30.0)

for h in pilot.history:
    img = h["image"]
    print(f"  payload {h['task_id']}: {img.arch}/{img.mode} "
          f"exit={h.get('exitcode')} bind={h['bind_seconds']*1e3:.1f}ms "
          f"cached={h['bind_cached']}")
print(f"  repo: {sim.repo.stats()}")
print("quickstart OK")
