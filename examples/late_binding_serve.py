"""Serving through late binding: one pilot-held slice serves BATCHED
requests for two different models back-to-back — the image swap replaces a
full re-provision between models.

  PYTHONPATH=src python examples/late_binding_serve.py
"""

import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core.arena import SharedArena
from repro.core.images import ExecutableRegistry, PayloadImage
from repro.core.latebind import PayloadExecutor, PodPatchCapability
from repro.core.proctable import ProcessTable
from repro.models.api import build_model
from repro.serving.engine import Request, ServeEngine

print("== batched serving via late binding ==")

arena = SharedArena()
registry = ExecutableRegistry()
executor = PayloadExecutor("pod-serve", arena, ProcessTable(), registry)
cap = PodPatchCapability("pod-serve")

rng = np.random.default_rng(0)
ARCHS = ("smollm-360m", "gemma-2b")
for n, arch in enumerate(ARCHS):
    t0 = time.monotonic()
    image = PayloadImage(arch, "smoke", "decode")
    executor.patch_image(cap, image)         # the unprivileged image swap
    bind_ms = (time.monotonic() - t0) * 1e3
    if n + 1 < len(ARCHS):                   # overlap the NEXT image's pull
        registry.prefetch(PayloadImage(ARCHS[n + 1], "smoke", "decode"))

    cfg = get_smoke_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    engine = ServeEngine(cfg, params, slots=2, max_len=64)
    for i in range(4):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8),
            max_new_tokens=6))
    stats = engine.run()
    print(f"  {arch}: bind {bind_ms:.1f} ms -> {stats['completed']} requests, "
          f"{stats['tok_per_s']:.1f} tok/s, "
          f"util {stats['slot_utilization']:.2f}")
    executor.reset()                         # cleanup between models (§3.6)
    arena.wipe_shared()

arena.destroy()
print("late-binding serve OK")
