"""Paper PoC #1 — the *fixed sequence* pod (paper §4).

The paper's first proof-of-concept YAML runs a pre-scripted sequence:
a pilot container and a payload container sharing a volume; the payload
waits for a startup script; the pilot writes it; the payload runs and
reports its exit code through the shared volume.  No scheduler, no
matchmaking — just the enabling mechanisms, in order.

  PYTHONPATH=src python examples/fixed_sequence.py
"""

import jax

from repro.core.arena import SharedArena
from repro.core.images import ExecutableRegistry, PayloadImage
from repro.core.latebind import PayloadExecutor, PodPatchCapability
from repro.core.proctable import PAYLOAD_UID, PILOT_UID, ProcessTable

print("== fixed-sequence PoC (paper §4, first YAML) ==")

# Pod creation: shared volume + both containers; payload holds the
# placeholder image and blocks on the startup-script path.
arena = SharedArena()
proctable = ProcessTable()
registry = ExecutableRegistry()
executor = PayloadExecutor("pod-poc", arena, proctable, registry)
print(f"1. pod created; payload container image = {executor.image.arch!r} "
      f"(placeholder), state = {executor.state}")

# The fixed sequence: the pilot already knows which image it will run.
cap = PodPatchCapability("pod-poc")
image = PayloadImage("smollm-360m", "smoke", "decode")
executor.patch_image(cap, image)
print(f"2. pod patch: payload image -> {image.arch}/{image.mode} "
      f"(bind {executor.last_bind_seconds*1e3:.1f} ms, unprivileged)")

executor.start(spec_timeout=10.0)
print("3. payload container started; waiting on startup script ...")

arena.write_env({"seed": 0, "greeting": "from-the-pilot"})
arena.publish_startup_spec({"n_steps": 3})
print("4. pilot wrote env + startup script into the shared volume")

executor.join(timeout=120.0)
exit_info = arena.read_exit()
print(f"5. payload finished: exit={exit_info['exitcode']} "
      f"steps={exit_info['telemetry']['steps']} "
      f"(relayed via exitcode.json, §3.5)")

# §3.4: the pilot saw the payload's 'process' the whole time
entries = proctable.entries(uid=PAYLOAD_UID, viewer_uid=PILOT_UID)
print(f"6. process table (pilot view): "
      f"{[(e.name, e.state, e.exitcode) for e in entries]}")

executor.reset()
arena.wipe_shared()
print(f"7. cleanup by container restart; shared volume now: "
      f"{arena.shared_files()}")
arena.destroy()
print("fixed-sequence PoC OK")
