"""Paper PoC #2 — the *fully dynamic* pod (paper §4): the payload image is
not known until the pilot fetches work from the task repository, and one
pilot serves several payloads (different models!) over its lifetime.

Also demonstrates the fault-tolerance substrate: a second run injects a
node failure mid-payload and shows lease-expiry re-queue + checkpoint
resume on a replacement pilot.

  PYTHONPATH=src python examples/dynamic_pilot.py
"""

import tempfile
import time

from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig
from repro.core.taskrepo import TaskRepo

print("== dynamic PoC (paper §4, second YAML): image fetched at runtime ==")
sim = ClusterSim()
tasks = {
    "train smollm": sim.repo.submit(
        PayloadImage("smollm-360m", "smoke", "train"), n_steps=3, priority=2),
    "serve gemma": sim.repo.submit(
        PayloadImage("gemma-2b", "smoke", "decode"), n_steps=4),
    "serve mamba2": sim.repo.submit(
        PayloadImage("mamba2-370m", "smoke", "decode"), n_steps=4),
}
(s,) = sim.provision(1)
pilot = sim.spawn_pilot(s, PilotConfig(max_payloads=5, idle_grace=1.0))
assert sim.run_until_drained(timeout=300.0)
sim.join_all(30.0)
for h in pilot.history:
    print(f"  ran {h['image'].arch}/{h['image'].mode}: exit={h.get('exitcode')}"
          f" bind_cached={h['bind_cached']}")

print("== failure injection: lease re-queue + checkpoint resume ==")
repo = TaskRepo(lease_ttl=2.0)
sim2 = ClusterSim(repo=repo)
ck = tempfile.mkdtemp(prefix="pilot_ck_")
tid = repo.submit(PayloadImage("smollm-360m", "smoke", "train"),
                  n_steps=200, max_attempts=5,
                  resume={"ckpt_dir": ck, "ckpt_every": 10})
(s1,) = sim2.provision(1)
p1 = sim2.spawn_pilot(s1, PilotConfig(max_payloads=2, idle_grace=0.5))
# kill the node only once at least one checkpoint exists (deterministic demo)
from repro.ckpt import checkpoint as ckpt_mod
deadline = time.monotonic() + 240
while ckpt_mod.latest_step(ck) is None and time.monotonic() < deadline:
    time.sleep(0.25)
sim2.fail_node(s1.slice_id)
p1.join(30.0)
print(f"  pilot 1 ({p1.pilot_id}): state={p1.state} (hard node loss)")

(s2,) = sim2.provision(1)
sim2.spawn_pilot(s2, PilotConfig(max_payloads=2, idle_grace=3.0))
assert sim2.run_until_drained(timeout=300.0)
sim2.join_all(30.0)
res = repo.result(tid)
print(f"  pilot 2 ({res.pilot_id}): exit={res.exitcode} "
      f"resumed_from={res.telemetry.get('resumed_from')} "
      f"steps_run={res.telemetry.get('steps')}")
print("dynamic PoC OK")
