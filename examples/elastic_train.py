"""Elastic scaling demo: the data-parallel mesh follows the live-pilot set.

Two pilots drain a queue of training payloads; one is then drained
(graceful scale-down) and the launcher recomputes the mesh via
`plan_remesh` — the model axis is untouched, the data axis shrinks, and
training resumes from the checkpoint.

  PYTHONPATH=src python examples/elastic_train.py
"""

from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig
from repro.runtime.mesh import MeshSpec

print("== elastic scale-down ==")
sim = ClusterSim()
for i in range(4):
    sim.repo.submit(PayloadImage("smollm-360m", "smoke", "train"), n_steps=2)

fleet = sim.spawn_fleet(2, PilotConfig(max_payloads=6, idle_grace=2.0))
plan0 = sim.remesh_plan(model_parallel=16, global_batch=256)
print(f"  2 live pilots -> mesh {plan0.new_mesh.shape} "
      f"(per-slice batch {plan0.new_per_data})")

(victim,) = fleet.scale_down(1)          # graceful drain, event-driven
victim.join(60.0)
plan1 = sim.remesh_plan(model_parallel=16, global_batch=256,
                        old=plan0.new_mesh)
print(f"  after drain ({victim.state}) -> mesh {plan1.new_mesh.shape} "
      f"(per-slice batch {plan1.new_per_data}); actions: {plan1.actions}")

assert fleet.await_drained(timeout=300.0)
print(f"  queue drained by the remaining pilot: {sim.repo.stats()}")
fleet.join_all(30.0)

# grow back: three fresh slices join the fleet
print("== elastic scale-up ==")
fleet.scale_up(3)
plan2 = sim.remesh_plan(model_parallel=16, global_batch=256,
                        old=plan1.new_mesh)
print(f"  {fleet.size()} live pilots -> mesh {plan2.new_mesh.shape} "
      f"(per-slice batch {plan2.new_per_data}); actions: {plan2.actions}")
fleet.join_all(30.0)
print("elastic demo OK")
